//! Parameter-server loop: broadcast → collect → select participants →
//! decode → consensus → step → project (Algorithm 3's server side, over
//! any [`ServerTransport`]).
//!
//! The round loop itself is allocation-free in steady state on the
//! in-process transport: decode scratch lives in per-worker
//! [`DecodeSlot`]s, arrivals collect into a reused vector, participant
//! selection is an in-place sort, and broadcast/wire buffers recycle
//! through the run's
//! [`ChannelPools`](crate::coordinator::channel::ChannelPools) —
//! `rust/tests/test_alloc.rs` proves this on the sequential decode path
//! (`n <` the threshold). Above the threshold the decode deliberately
//! spends participant-many scoped-thread spawns per round to parallelize
//! the `O(N log N)` inverse transforms — stack setup is the price of the
//! fan-out, while the decoded data still lands in the same warm,
//! recycled buffers. At very large `n` the transform *inside* each decode
//! additionally goes multi-threaded (the FWHT dispatches through
//! [`fwht_inplace_auto`](crate::linalg::fwht::fwht_inplace_auto) above
//! [`MT_FWHT_MIN_DIM`](crate::coordinator::config::MT_FWHT_MIN_DIM));
//! that threshold sits deliberately above [`PARALLEL_DECODE_MIN_DIM`] so
//! the two fan-outs do not nest at moderate dimensions.
//!
//! It is also *seed-deterministic*: the server always collects exactly
//! `m` frames per round (the transport marks lost frames instead of
//! withholding them), the participation policy picks a subset as a pure
//! function of `(arrival times, seed, round)`, participants are sorted
//! by worker id before decoding, and the consensus accumulates in that
//! order — so the iterates are identical regardless of upload arrival
//! order and of whether the decode ran sequentially or on scoped
//! threads.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::config::RunConfig;
use crate::coordinator::metrics::{RoundMetrics, RunMetrics};
use crate::coordinator::protocol::Broadcast;
use crate::coordinator::transport::{select_participants, Arrival, ServerTransport};
use crate::opt::projection::Domain;
use crate::quant::{Compressor, Workspace};

pub use crate::coordinator::config::PARALLEL_DECODE_MIN_DIM;

/// Per-worker decode scratch: a codec workspace plus the decoded-output
/// buffer, allocated once per run.
struct DecodeSlot {
    ws: Workspace,
    q: Vec<f32>,
}

/// Decode the round's participating uploads into the consensus average
/// (mean over the participants). One scoped thread per upload when `n`
/// is large enough to amortize the spawns.
///
/// Precondition: `participants` is sorted by worker id —
/// [`select_participants`]' documented postcondition — and the decoded
/// estimates are accumulated in that order, so the result is
/// bit-identical between the sequential and the threaded path *and*
/// across runs (upload arrival order is scheduler-dependent; worker-id
/// order is not).
fn decode_round(
    consensus: &mut [f32],
    participants: &[Arrival],
    compressors: &[Arc<dyn Compressor>],
    slots: &mut [DecodeSlot],
    parallel_min_dim: usize,
) {
    let p = participants.len();
    if p == 0 {
        return;
    }
    let n = consensus.len();
    debug_assert!(
        participants.windows(2).all(|w| w[0].up.worker <= w[1].up.worker),
        "decode_round requires worker-id-sorted participants"
    );
    if p > 1 && n >= parallel_min_dim {
        std::thread::scope(|s| {
            for (a, slot) in participants.iter().zip(slots.iter_mut()) {
                let comp = &compressors[a.up.worker];
                s.spawn(move || comp.decompress_into(&a.up.msg, &mut slot.ws, &mut slot.q));
            }
        });
    } else {
        for (a, slot) in participants.iter().zip(slots.iter_mut()) {
            compressors[a.up.worker].decompress_into(&a.up.msg, &mut slot.ws, &mut slot.q);
        }
    }
    for slot in slots[..p].iter() {
        for (c, &qi) in consensus.iter_mut().zip(&slot.q) {
            *c += qi / p as f32;
        }
    }
}

/// Server loop over an abstract transport. `eval` computes the global
/// objective value of an iterate (for metrics; pass a cheap proxy for
/// expensive models).
pub fn server_loop(
    cfg: &RunConfig,
    x0: Vec<f32>,
    transport: &mut dyn ServerTransport,
    compressors: &[Arc<dyn Compressor>],
    mut eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = transport.workers();
    let n = cfg.n;
    assert_eq!(x0.len(), n, "x0 dimension mismatch");
    let domain = if cfg.radius.is_finite() {
        Domain::L2Ball { radius: cfg.radius }
    } else {
        Domain::Unconstrained
    };
    let mut x = x0;
    domain.project(&mut x);
    let mut consensus = vec![0.0f32; n];
    let mut metrics =
        RunMetrics { rounds: Vec::with_capacity(cfg.rounds), ..Default::default() };
    // Per-run preallocation: arrival collection vector and per-worker
    // decode slots. Nothing below this line allocates in steady state
    // (on the in-process transport).
    let mut arrivals: Vec<Arrival> = Vec::with_capacity(m);
    let mut slots: Vec<DecodeSlot> = compressors
        .iter()
        .map(|c| DecodeSlot { ws: Workspace::for_compressor(c.as_ref()), q: vec![0.0f32; n] })
        .collect();

    for round in 0..cfg.rounds as u64 {
        let t0 = Instant::now();
        // Broadcast the iterate: one recycled buffer per worker (fresh
        // only during warm-up; workers return them before uploading).
        for w in 0..m {
            let mut it = transport.pools().iterates.get_or(|| Vec::with_capacity(n));
            it.clear();
            it.extend_from_slice(&x);
            // A dead worker (or a failed trace write) is fatal: the
            // consensus average would silently change semantics, so
            // surface it — with the transport's own diagnosis, since
            // "worker hung up" and "disk full" need different fixes.
            transport
                .broadcast(w, Broadcast { round, iterate: it })
                .unwrap_or_else(|e| panic!("broadcast to worker {w} failed at round {round}: {e}"));
        }
        // Collect exactly m frames for this round (workers answer every
        // broadcast exactly once — lost frames arrive *marked*, not
        // missing — so rounds cannot interleave)...
        let mut round_bits = 0usize;
        arrivals.clear();
        for _ in 0..m {
            let a = transport
                .recv()
                .unwrap_or_else(|e| panic!("uplink failed at round {round}: {e}"));
            assert_eq!(a.up.round, round, "round skew: got {} want {round}", a.up.round);
            assert_eq!(
                a.up.msg.n, n,
                "dimension skew: frame carries n={}, config says {n} \
                 (replaying a trace recorded at a different dimension?)",
                a.up.msg.n
            );
            round_bits += a.up.msg.payload_bits;
            arrivals.push(a);
        }
        // ...then let the participation policy pick which delivered
        // frames join the consensus, and decode those — in parallel when
        // the dimension warrants it.
        let p = select_participants(&mut arrivals, cfg.participation, round, cfg.seed);
        consensus.fill(0.0);
        decode_round(
            &mut consensus,
            &arrivals[..p],
            compressors,
            &mut slots,
            cfg.parallel_decode_min_dim,
        );
        // Participants are worker-id-sorted after decode_round: sum the
        // local values in that (deterministic) order, then recycle every
        // frame's wire buffer — non-participants' too — for the workers'
        // next round.
        let mut local_sum = 0.0f64;
        for a in arrivals[..p].iter() {
            local_sum += a.up.local_value as f64;
        }
        for a in arrivals.iter_mut() {
            transport.pools().bytes.put(std::mem::take(&mut a.up.msg.bytes));
        }
        // Step + project (a zero-participant round leaves x unchanged —
        // the consensus estimate is zero).
        for (xi, &ci) in x.iter_mut().zip(&consensus) {
            *xi -= cfg.step * ci;
        }
        domain.project(&mut x);
        metrics.rounds.push(RoundMetrics {
            round,
            value: eval(&x),
            mean_local_value: if p > 0 { (local_sum / p as f64) as f32 } else { f32::NAN },
            payload_bits: round_bits,
            participants: p,
            wall: t0.elapsed(),
        });
    }
    let traffic = transport.traffic();
    metrics.total_payload_bits = traffic.payload_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.total_overhead_bits = traffic.overhead_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.rejected_messages = traffic.rejected.load(std::sync::atomic::Ordering::Relaxed);
    metrics.final_iterate = x;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeKind;
    use crate::coordinator::run_distributed;
    use crate::coordinator::worker::DatasetGradSource;
    use crate::data::synthetic::planted_regression_shards;
    use crate::linalg::rng::Rng;
    use crate::opt::objectives::Loss;

    /// End-to-end: 4 workers, NDSC at R=2, planted regression — global
    /// loss must drop by >10x and the budget must hold exactly.
    #[test]
    fn distributed_regression_converges() {
        let mut rng = Rng::seed_from(1);
        let (shards, _xs) =
            planted_regression_shards(4, 12, 16, Loss::Square, &mut rng, false);
        let global: Vec<_> = shards.clone();
        let cfg = RunConfig {
            n: 16,
            workers: 4,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 150,
            step: 0.02,
            batch: 0,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let sources: Vec<Box<dyn crate::coordinator::worker::GradSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(i, obj)| {
                Box::new(DatasetGradSource {
                    obj,
                    batch: 0,
                    rng: Rng::seed_from(100 + i as u64),
                    idx: Vec::new(),
                }) as Box<dyn crate::coordinator::worker::GradSource>
            })
            .collect();
        let metrics = run_distributed(&cfg, vec![0.0; 16], sources, comps, |x| {
            global.iter().map(|s| s.value(x)).sum::<f32>() / 4.0
        });
        assert_eq!(metrics.rounds.len(), 150);
        assert_eq!(metrics.rejected_messages, 0);
        assert!(metrics.rounds.iter().all(|r| r.participants == 4));
        let first = metrics.rounds[0].value;
        let last = metrics.final_value();
        assert!(last < 0.1 * first, "loss {first} -> {last}");
        // Exact budget: every round, every worker sends floor(16*2)=32 bits.
        assert_eq!(metrics.total_payload_bits, 150 * 4 * 32);
        assert!((metrics.mean_rate(16, 4) - 2.0).abs() < 1e-6);
    }
}
