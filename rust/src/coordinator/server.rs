//! Parameter-server loop: broadcast → collect → decode → consensus →
//! step → project (Algorithm 3's server side).
//!
//! The round loop itself is allocation-free in steady state: decode
//! scratch lives in per-worker [`DecodeSlot`]s, uploads collect into a
//! reused vector, and broadcast/wire buffers recycle through the run's
//! [`ChannelPools`](crate::coordinator::channel::ChannelPools) —
//! `rust/tests/test_alloc.rs` proves this on the sequential decode path
//! (`n <` the threshold). Above the threshold the decode deliberately
//! spends `m` scoped-thread spawns per round to parallelize the
//! `O(N log N)` inverse transforms — stack setup is the price of the
//! fan-out, while the decoded data still lands in the same warm,
//! recycled buffers. It is also
//! *seed-deterministic*: uploads are sorted by worker id before decoding
//! and accumulated in that order, so the consensus iterates are identical
//! regardless of upload arrival order and of whether the decode ran
//! sequentially or on scoped threads.

use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::channel::{ChannelPools, TrafficCounter};
use crate::coordinator::config::RunConfig;
use crate::coordinator::metrics::{RoundMetrics, RunMetrics};
use crate::coordinator::protocol::{Broadcast, Upload};
use crate::opt::projection::Domain;
use crate::quant::{Compressor, Workspace};

/// Default dimension at which the server fans the per-round decode out
/// across scoped threads. Below this, a decode is a few microseconds of
/// work and a thread spawn would cost more than it saves; above it (the
/// (N)DSC decode is an `O(N log N)` FWHT plus an `O(N)` inverse transform,
/// and the transformer workload has `n ~ 10^5`) the `m`-way fan-out is a
/// near-linear speedup of the consensus step. Override per run via
/// [`RunConfig::parallel_decode_min_dim`] (tests force both paths with it).
pub const PARALLEL_DECODE_MIN_DIM: usize = 8192;

/// Per-worker decode scratch: a codec workspace plus the decoded-output
/// buffer, allocated once per run.
struct DecodeSlot {
    ws: Workspace,
    q: Vec<f32>,
}

/// Decode the round's uploads into the consensus average. One scoped
/// thread per upload when `n` is large enough to amortize the spawns.
/// Uploads are first sorted by worker id and the decoded estimates are
/// accumulated in that order, so the result is bit-identical between the
/// sequential and the threaded path *and* across runs (upload arrival
/// order is scheduler-dependent; worker-id order is not).
fn decode_round(
    consensus: &mut [f32],
    ups: &mut [Upload],
    compressors: &[Arc<dyn Compressor>],
    slots: &mut [DecodeSlot],
    parallel_min_dim: usize,
) {
    let m = ups.len();
    let n = consensus.len();
    ups.sort_unstable_by_key(|up| up.worker);
    if m > 1 && n >= parallel_min_dim {
        std::thread::scope(|s| {
            for (up, slot) in ups.iter().zip(slots.iter_mut()) {
                let comp = &compressors[up.worker];
                s.spawn(move || comp.decompress_into(&up.msg, &mut slot.ws, &mut slot.q));
            }
        });
    } else {
        for (up, slot) in ups.iter().zip(slots.iter_mut()) {
            compressors[up.worker].decompress_into(&up.msg, &mut slot.ws, &mut slot.q);
        }
    }
    for slot in slots.iter() {
        for (c, &qi) in consensus.iter_mut().zip(&slot.q) {
            *c += qi / m as f32;
        }
    }
}

/// Server loop. `eval` computes the global objective value of an iterate
/// (for metrics; pass a cheap proxy for expensive models).
#[allow(clippy::too_many_arguments)]
pub fn server_loop(
    cfg: &RunConfig,
    x0: Vec<f32>,
    downlinks: &[SyncSender<Broadcast>],
    uplink: &Receiver<Upload>,
    compressors: &[Arc<dyn Compressor>],
    pools: &ChannelPools,
    traffic: Arc<TrafficCounter>,
    mut eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = downlinks.len();
    let n = cfg.n;
    assert_eq!(x0.len(), n, "x0 dimension mismatch");
    let domain = if cfg.radius.is_finite() {
        Domain::L2Ball { radius: cfg.radius }
    } else {
        Domain::Unconstrained
    };
    let mut x = x0;
    domain.project(&mut x);
    let mut consensus = vec![0.0f32; n];
    let mut metrics =
        RunMetrics { rounds: Vec::with_capacity(cfg.rounds), ..Default::default() };
    // Per-run preallocation: upload collection vector and per-worker
    // decode slots. Nothing below this line allocates in steady state.
    let mut ups: Vec<Upload> = Vec::with_capacity(m);
    let mut slots: Vec<DecodeSlot> = compressors
        .iter()
        .map(|c| DecodeSlot { ws: Workspace::for_compressor(c.as_ref()), q: vec![0.0f32; n] })
        .collect();

    for round in 0..cfg.rounds as u64 {
        let t0 = Instant::now();
        // Broadcast the iterate: one recycled buffer per worker (fresh
        // only during warm-up; workers return them before uploading).
        for tx in downlinks {
            let mut it = pools.iterates.get_or(|| Vec::with_capacity(n));
            it.clear();
            it.extend_from_slice(&x);
            // A dead worker is fatal: the consensus average would silently
            // change semantics, so surface it.
            tx.send(Broadcast { round, iterate: it }).expect("worker hung up");
        }
        // Collect exactly m uploads for this round (workers answer every
        // broadcast exactly once; rounds cannot interleave), then decode
        // them — in parallel when the dimension warrants it.
        consensus.fill(0.0);
        let mut round_bits = 0usize;
        ups.clear();
        for _ in 0..m {
            let up = uplink.recv().expect("all workers disconnected");
            assert_eq!(up.round, round, "round skew: got {} want {round}", up.round);
            round_bits += up.msg.payload_bits;
            ups.push(up);
        }
        decode_round(&mut consensus, &mut ups, compressors, &mut slots, cfg.parallel_decode_min_dim);
        // `ups` is worker-id-sorted after decode_round: sum the local
        // values in that (deterministic) order, then recycle the spent
        // wire buffers for the workers' next round.
        let mut local_sum = 0.0f64;
        for up in ups.iter_mut() {
            local_sum += up.local_value as f64;
            pools.bytes.put(std::mem::take(&mut up.msg.bytes));
        }
        // Step + project.
        for (xi, &ci) in x.iter_mut().zip(&consensus) {
            *xi -= cfg.step * ci;
        }
        domain.project(&mut x);
        metrics.rounds.push(RoundMetrics {
            round,
            value: eval(&x),
            mean_local_value: (local_sum / m as f64) as f32,
            payload_bits: round_bits,
            wall: t0.elapsed(),
        });
    }
    metrics.total_payload_bits = traffic.payload_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.total_overhead_bits = traffic.overhead_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.rejected_messages = traffic.rejected.load(std::sync::atomic::Ordering::Relaxed);
    metrics.final_iterate = x;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeKind;
    use crate::coordinator::run_distributed;
    use crate::coordinator::worker::DatasetGradSource;
    use crate::data::synthetic::planted_regression_shards;
    use crate::linalg::rng::Rng;
    use crate::opt::objectives::Loss;

    /// End-to-end: 4 workers, NDSC at R=2, planted regression — global
    /// loss must drop by >10x and the budget must hold exactly.
    #[test]
    fn distributed_regression_converges() {
        let mut rng = Rng::seed_from(1);
        let (shards, _xs) =
            planted_regression_shards(4, 12, 16, Loss::Square, &mut rng, false);
        let global: Vec<_> = shards.clone();
        let cfg = RunConfig {
            n: 16,
            workers: 4,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 150,
            step: 0.02,
            batch: 0,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let sources: Vec<Box<dyn crate::coordinator::worker::GradSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(i, obj)| {
                Box::new(DatasetGradSource {
                    obj,
                    batch: 0,
                    rng: Rng::seed_from(100 + i as u64),
                    idx: Vec::new(),
                }) as Box<dyn crate::coordinator::worker::GradSource>
            })
            .collect();
        let metrics = run_distributed(&cfg, vec![0.0; 16], sources, comps, |x| {
            global.iter().map(|s| s.value(x)).sum::<f32>() / 4.0
        });
        assert_eq!(metrics.rounds.len(), 150);
        assert_eq!(metrics.rejected_messages, 0);
        let first = metrics.rounds[0].value;
        let last = metrics.final_value();
        assert!(last < 0.1 * first, "loss {first} -> {last}");
        // Exact budget: every round, every worker sends floor(16*2)=32 bits.
        assert_eq!(metrics.total_payload_bits, 150 * 4 * 32);
        assert!((metrics.mean_rate(16, 4) - 2.0).abs() < 1e-6);
    }
}
