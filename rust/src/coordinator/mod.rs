//! L3 distributed runtime: a parameter server and `m` workers exchanging
//! bit-budgeted gradient messages over a pluggable, byte-accounted
//! [`transport`] (§4.3, Fig. 4 of the paper).
//!
//! The topology is the paper's star: per round the server broadcasts the
//! iterate, every worker computes a local (mini-batch) subgradient from
//! its private shard, encodes it with its own `(E, D)` pair under its own
//! strict `⌊n·R_i⌋`-bit budget, and the server decodes, averages the
//! [`transport::Participation`]-selected subset (consensus step), steps
//! and projects. The uplink — the constrained direction in the paper —
//! flows through budget-enforcing, byte-tallying channels that reject
//! over-budget payloads.
//!
//! Delivery itself is owned by the [`transport`] layer: in-process
//! channels ([`transport::inproc`], bit-identical to the classic path),
//! a deterministic seeded latency/jitter/drop/topology model
//! ([`transport::simnet`] — stragglers and lossy links), or a recording
//! wrapper whose traces [`replay_distributed`] re-runs to identical
//! server iterates ([`transport::recorded`]).
//!
//! Workers run on `std::thread` (this image has no tokio); the gradient
//! source is pluggable ([`worker::GradSource`]) so the same loop drives
//! pure-Rust objectives and PJRT-compiled transformer workers
//! (`examples/train_transformer.rs`).
//!
//! **Steady-state rounds are allocation-free** on the in-process
//! transport: channels are bounded (ring buffers allocated at setup),
//! broadcast iterates and uplink wire bytes recycle through
//! [`channel::ChannelPools`], every worker owns a warm
//! [`crate::quant::Workspace`], and the server decodes into per-worker
//! slots — `rust/tests/test_alloc.rs` asserts the round loop performs
//! zero heap allocations after warm-up.

pub mod channel;
pub mod config;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod transport;
pub mod worker;

use std::sync::Arc;

use crate::linalg::rng::Rng;
use crate::quant::Compressor;

use config::RunConfig;
use metrics::RunMetrics;
use transport::ServerTransport;
use worker::GradSource;

/// Run a full distributed job: builds the configured transport, spawns
/// one scoped thread per worker, runs the server loop on the calling
/// thread, returns the metrics log.
///
/// `sources[i]` is worker `i`'s private gradient source; `compressors[i]`
/// its codec at its own budget `R_i` (shared by value with the server for
/// decoding — the frame randomness is common randomness established at
/// setup, as in the paper).
///
/// The per-round fan-out is fully thread-parallel: all `m` workers
/// compute/compress/upload concurrently on their own scoped threads, and
/// the server additionally fans the per-round *decode* out across scoped
/// threads when the dimension makes it worthwhile (see
/// [`config::PARALLEL_DECODE_MIN_DIM`]). `std::thread::scope` both joins
/// the workers automatically and lifts any `'static` requirement on
/// gradient sources.
pub fn run_distributed(
    cfg: &RunConfig,
    x0: Vec<f32>,
    sources: Vec<Box<dyn GradSource>>,
    compressors: Vec<Arc<dyn Compressor>>,
    eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = sources.len();
    assert_eq!(m, cfg.workers);
    assert_eq!(compressors.len(), m);
    for c in &compressors {
        assert_eq!(c.n(), cfg.n, "compressor dim mismatch");
    }

    std::thread::scope(|scope| {
        // Built *inside* the scope closure on purpose: if the server loop
        // panics (dead worker, round skew), unwinding drops the transport
        // — and with it every downlink sender — so blocked workers see a
        // closed channel and exit, the scope's join completes, and the
        // panic propagates instead of deadlocking the join.
        let (mut server_tp, worker_tps) =
            transport::build(&cfg.transport, &cfg.uplink_budgets());
        let mut root_rng = Rng::seed_from(cfg.seed ^ 0xD15C0);
        for (i, ((mut source, comp), mut wtp)) in sources
            .into_iter()
            .zip(compressors.iter().cloned())
            .zip(worker_tps)
            .enumerate()
        {
            let mut wrng = root_rng.fork(i as u64);
            let wpools = server_tp.pools().clone();
            scope.spawn(move || {
                worker::worker_loop(
                    i,
                    &mut *source,
                    comp.as_ref(),
                    wtp.as_mut(),
                    &wpools,
                    &mut wrng,
                );
            });
        }

        let metrics = server::server_loop(cfg, x0, server_tp.as_mut(), &compressors, eval);

        // Close the downlinks (and flush any trace file): workers see a
        // closed channel and exit; the scope joins them (propagating any
        // worker panic).
        server_tp.finish();
        metrics
    })
}

/// Re-run the server side of a recorded job from its trace file alone:
/// no workers, no gradient sources — `recv` hands back the recorded wire
/// frames in order. With the same `cfg` and the same compressors (same
/// setup seed ⇒ same common randomness) the replay reproduces the
/// original run's server iterates bit-for-bit
/// (`rust/tests/test_transport.rs`).
pub fn replay_distributed(
    cfg: &RunConfig,
    x0: Vec<f32>,
    compressors: &[Arc<dyn Compressor>],
    path: &str,
    eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let mut tp = transport::replay(path)
        .unwrap_or_else(|e| panic!("cannot load trace '{path}': {e}"));
    assert_eq!(
        tp.workers(),
        cfg.workers,
        "trace was recorded with {} workers, config says {}",
        tp.workers(),
        cfg.workers
    );
    server::server_loop(cfg, x0, &mut tp, compressors, eval)
}
