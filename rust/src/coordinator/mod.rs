//! L3 distributed runtime: a parameter server and `m` workers exchanging
//! bit-budgeted gradient messages over byte-accounted channels (§4.3,
//! Fig. 4 of the paper).
//!
//! The topology is the paper's: per round the server broadcasts the
//! iterate, every worker computes a local (mini-batch) subgradient from its
//! private shard, encodes it with its own `(E, D)` pair under the strict
//! `⌊nR⌋`-bit budget, and the server decodes, averages (consensus step),
//! steps and projects. The uplink — the constrained direction in the paper
//! — flows through [`channel::AccountedChannel`]s that reject over-budget
//! payloads and tally every byte.
//!
//! Workers run on `std::thread` (this image has no tokio); the gradient
//! source is pluggable ([`worker::GradSource`]) so the same loop drives
//! pure-Rust objectives and PJRT-compiled transformer workers
//! (`examples/train_transformer.rs`).

pub mod channel;
pub mod config;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod worker;

use std::sync::mpsc;
use std::sync::Arc;

use crate::linalg::rng::Rng;
use crate::quant::Compressor;

use channel::AccountedSender;
use config::RunConfig;
use metrics::RunMetrics;
use protocol::{Broadcast, Upload};
use worker::GradSource;

/// Run a full distributed job: spawns one scoped thread per worker, runs
/// the server loop on the calling thread, returns the metrics log.
///
/// `sources[i]` is worker `i`'s private gradient source; `compressors[i]`
/// its codec (shared by value with the server for decoding — the frame
/// randomness is common randomness established at setup, as in the paper).
///
/// The per-round fan-out is fully thread-parallel: all `m` workers
/// compute/compress/upload concurrently on their own scoped threads, and
/// the server additionally fans the per-round *decode* out across scoped
/// threads when the dimension makes it worthwhile (see
/// [`server::PARALLEL_DECODE_MIN_DIM`]). `std::thread::scope` both joins
/// the workers automatically and lifts the old `'static` requirement on
/// gradient sources.
pub fn run_distributed(
    cfg: &RunConfig,
    x0: Vec<f32>,
    sources: Vec<Box<dyn GradSource>>,
    compressors: Vec<Arc<dyn Compressor>>,
    eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = sources.len();
    assert_eq!(m, cfg.workers);
    assert_eq!(compressors.len(), m);
    for c in &compressors {
        assert_eq!(c.n(), cfg.n, "compressor dim mismatch");
    }

    // Uplink: workers -> server, budget-enforced + byte-accounted.
    let (up_tx, up_rx) = mpsc::channel::<Upload>();
    let budget_bits = crate::quant::budget_bits(cfg.n, cfg.r);
    let uplink = AccountedSender::new(up_tx, Some(budget_bits));
    let mut root_rng = Rng::seed_from(cfg.seed ^ 0xD15C0);

    std::thread::scope(|scope| {
        // Downlinks: server -> each worker (broadcast is m sends).
        let mut down_txs = Vec::with_capacity(m);
        for (i, (mut source, comp)) in
            sources.into_iter().zip(compressors.iter().cloned()).enumerate()
        {
            let (down_tx, down_rx) = mpsc::channel::<Broadcast>();
            down_txs.push(down_tx);
            let uplink = uplink.clone();
            let mut wrng = root_rng.fork(i as u64);
            scope.spawn(move || {
                worker::worker_loop(i, &mut *source, comp.as_ref(), down_rx, uplink, &mut wrng);
            });
        }

        // Drop the prototype sender: only worker clones remain, so a dead
        // worker is observable as a closed channel rather than a deadlock.
        let traffic = uplink.counter();
        drop(uplink);

        let metrics = server::server_loop(cfg, x0, &down_txs, &up_rx, &compressors, traffic, eval);

        // Downlink senders drop here => workers see a closed channel and
        // exit; the scope joins them (propagating any worker panic).
        drop(down_txs);
        metrics
    })
}
