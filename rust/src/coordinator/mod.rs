//! L3 distributed runtime: a parameter server and `m` workers exchanging
//! bit-budgeted gradient messages over byte-accounted channels (§4.3,
//! Fig. 4 of the paper).
//!
//! The topology is the paper's: per round the server broadcasts the
//! iterate, every worker computes a local (mini-batch) subgradient from its
//! private shard, encodes it with its own `(E, D)` pair under the strict
//! `⌊nR⌋`-bit budget, and the server decodes, averages (consensus step),
//! steps and projects. The uplink — the constrained direction in the paper
//! — flows through [`channel::AccountedChannel`]s that reject over-budget
//! payloads and tally every byte.
//!
//! Workers run on `std::thread` (this image has no tokio); the gradient
//! source is pluggable ([`worker::GradSource`]) so the same loop drives
//! pure-Rust objectives and PJRT-compiled transformer workers
//! (`examples/train_transformer.rs`).
//!
//! **Steady-state rounds are allocation-free**: channels are bounded
//! (ring buffers allocated at setup), broadcast iterates and uplink wire
//! bytes recycle through [`channel::ChannelPools`], every worker owns a
//! warm [`crate::quant::Workspace`], and the server decodes into
//! per-worker slots — `rust/tests/test_alloc.rs` asserts the round loop
//! performs zero heap allocations after warm-up.

pub mod channel;
pub mod config;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod worker;

use std::sync::mpsc;
use std::sync::Arc;

use crate::linalg::rng::Rng;
use crate::quant::Compressor;

use channel::{AccountedSender, ChannelPools};
use config::RunConfig;
use metrics::RunMetrics;
use protocol::{Broadcast, Upload};
use worker::GradSource;

/// Run a full distributed job: spawns one scoped thread per worker, runs
/// the server loop on the calling thread, returns the metrics log.
///
/// `sources[i]` is worker `i`'s private gradient source; `compressors[i]`
/// its codec (shared by value with the server for decoding — the frame
/// randomness is common randomness established at setup, as in the paper).
///
/// The per-round fan-out is fully thread-parallel: all `m` workers
/// compute/compress/upload concurrently on their own scoped threads, and
/// the server additionally fans the per-round *decode* out across scoped
/// threads when the dimension makes it worthwhile (see
/// [`server::PARALLEL_DECODE_MIN_DIM`]). `std::thread::scope` both joins
/// the workers automatically and lifts the old `'static` requirement on
/// gradient sources.
pub fn run_distributed(
    cfg: &RunConfig,
    x0: Vec<f32>,
    sources: Vec<Box<dyn GradSource>>,
    compressors: Vec<Arc<dyn Compressor>>,
    eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = sources.len();
    assert_eq!(m, cfg.workers);
    assert_eq!(compressors.len(), m);
    for c in &compressors {
        assert_eq!(c.n(), cfg.n, "compressor dim mismatch");
    }

    // Uplink: workers -> server, budget-enforced + byte-accounted. The
    // channel is *bounded* (ring buffer allocated once): workers send at
    // most one upload per round, so 2m slots never fill, and steady-state
    // sends touch no heap. The fp32 passthrough is the documented
    // *unconstrained* reference (exempt from `RunConfig::validate`'s
    // feasibility check for the same reason), so its uploads are not
    // budget-gated — every other scheme is held to ⌊n·R⌋ exactly.
    let (up_tx, up_rx) = mpsc::sync_channel::<Upload>(2 * m.max(1));
    let budget = if cfg.compressor_spec() == crate::quant::registry::CompressorSpec::Fp32 {
        None
    } else {
        Some(crate::quant::budget_bits(cfg.n, cfg.r))
    };
    let uplink = AccountedSender::new(up_tx, budget);
    // Buffer recycling (broadcast iterates + uplink wire bytes) shared by
    // the server and every worker thread.
    let pools = Arc::new(ChannelPools::new(m));
    let mut root_rng = Rng::seed_from(cfg.seed ^ 0xD15C0);

    std::thread::scope(|scope| {
        // Downlinks: server -> each worker (broadcast is m sends; at most
        // one broadcast is in flight per worker, so 2 slots suffice).
        let mut down_txs = Vec::with_capacity(m);
        for (i, (mut source, comp)) in
            sources.into_iter().zip(compressors.iter().cloned()).enumerate()
        {
            let (down_tx, down_rx) = mpsc::sync_channel::<Broadcast>(2);
            down_txs.push(down_tx);
            let uplink = uplink.clone();
            let mut wrng = root_rng.fork(i as u64);
            let wpools = pools.clone();
            scope.spawn(move || {
                worker::worker_loop(
                    i,
                    &mut *source,
                    comp.as_ref(),
                    down_rx,
                    uplink,
                    &wpools,
                    &mut wrng,
                );
            });
        }

        // Drop the prototype sender: only worker clones remain, so a dead
        // worker is observable as a closed channel rather than a deadlock.
        let traffic = uplink.counter();
        drop(uplink);

        let metrics =
            server::server_loop(cfg, x0, &down_txs, &up_rx, &compressors, &pools, traffic, eval);

        // Downlink senders drop here => workers see a closed channel and
        // exit; the scope joins them (propagating any worker panic).
        drop(down_txs);
        metrics
    })
}
