//! Worker loop: receive the broadcast iterate, evaluate the local
//! (sub)gradient, encode under the bit budget, upload.
//!
//! The loop owns a [`Workspace`] and recycles message buffers through the
//! run's [`ChannelPools`], so a steady-state round performs zero heap
//! allocations: the gradient buffer, the codec scratch and the wire bytes
//! are all reused round-over-round.

use std::sync::mpsc::Receiver;

use crate::coordinator::channel::{AccountedSender, ChannelError, ChannelPools};
use crate::coordinator::protocol::{Broadcast, Upload};
use crate::linalg::rng::Rng;
use crate::quant::{Compressed, Compressor, Workspace};

/// A worker's private gradient source. Implementations: pure-Rust dataset
/// shards ([`DatasetGradSource`]) and PJRT-compiled models (the transformer
/// example builds one over [`crate::runtime::Artifact`]).
pub trait GradSource: Send {
    fn dim(&self) -> usize;
    /// Write a local (mini-batch) subgradient at `x` into `out`; return the
    /// local objective value (metrics side channel).
    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> f32;
}

/// Minibatch gradient source over a private [`DatasetObjective`] shard.
pub struct DatasetGradSource {
    pub obj: crate::opt::objectives::DatasetObjective,
    /// 0 = full local gradient.
    pub batch: usize,
    pub rng: Rng,
    /// Reused minibatch index buffer (allocation-free steady state);
    /// start with `Vec::new()`.
    pub idx: Vec<usize>,
}

impl GradSource for DatasetGradSource {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        if self.batch == 0 || self.batch >= self.obj.m {
            self.obj.gradient(x, out);
        } else {
            self.rng.sample_indices_into(self.obj.m, self.batch, &mut self.idx);
            self.obj.minibatch_gradient(x, Some(&self.idx), out);
        }
        self.obj.value(x)
    }
}

/// The worker thread body: loops until the downlink closes.
///
/// Buffer recycling protocol: the broadcast's iterate buffer is returned to
/// `pools.iterates` as soon as the gradient is evaluated — *before* the
/// upload is sent — so the server is guaranteed to find `m` parked iterate
/// buffers once it has collected a round's `m` uploads. The wire-byte
/// buffer comes from `pools.bytes` (parked there by the server after the
/// previous round's decode).
pub fn worker_loop(
    id: usize,
    source: &mut dyn GradSource,
    compressor: &dyn Compressor,
    downlink: Receiver<Broadcast>,
    uplink: AccountedSender<Upload>,
    pools: &ChannelPools,
    rng: &mut Rng,
) {
    let n = source.dim();
    let mut g = vec![0.0f32; n];
    let mut ws = Workspace::for_compressor(compressor);
    while let Ok(bcast) = downlink.recv() {
        let local_value = source.grad(&bcast.iterate, &mut g);
        pools.iterates.put(bcast.iterate);
        let mut msg = Compressed {
            n,
            bytes: pools.bytes.get_or(Vec::new),
            payload_bits: 0,
            side_bits: 0,
        };
        compressor.compress_into(&g, rng, &mut ws, &mut msg);
        match uplink.send(Upload { round: bcast.round, worker: id, msg, local_value }) {
            Ok(()) => {}
            Err(ChannelError::OverBudget { payload_bits, budget_bits }) => {
                // A correct compressor never trips this; it is the runtime
                // guard against mis-configured schemes.
                panic!(
                    "worker {id}: compressor '{}' exceeded budget ({payload_bits} > {budget_bits} bits)",
                    compressor.name()
                );
            }
            Err(ChannelError::Disconnected(_)) => break, // server gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{planted_regression, Tail};
    use crate::quant::ndsc::Ndsc;
    use std::sync::mpsc;

    #[test]
    fn worker_responds_to_each_broadcast() {
        let mut rng = Rng::seed_from(1);
        let (obj, _) = planted_regression(20, 8, Tail::Gaussian, Tail::Gaussian, 0.0, &mut rng);
        let mut source =
            DatasetGradSource { obj, batch: 0, rng: Rng::seed_from(2), idx: Vec::new() };
        let comp = Ndsc::hadamard(8, 2.0, &mut rng);
        let (down_tx, down_rx) = mpsc::sync_channel(4);
        let (up_tx, up_rx) = mpsc::sync_channel(4);
        let uplink = AccountedSender::new(up_tx, Some(crate::quant::budget_bits(8, 2.0)));
        let mut wrng = Rng::seed_from(3);
        let handle = std::thread::spawn(move || {
            let pools = ChannelPools::new(1);
            worker_loop(7, &mut source, &comp, down_rx, uplink, &pools, &mut wrng);
        });
        for round in 0..5u64 {
            down_tx.send(Broadcast { round, iterate: vec![0.1; 8] }).unwrap();
            let up = up_rx.recv().unwrap();
            assert_eq!(up.round, round);
            assert_eq!(up.worker, 7);
            assert!(up.msg.payload_bits <= 16);
            assert!(up.local_value.is_finite());
        }
        drop(down_tx);
        handle.join().unwrap();
    }

    #[test]
    fn dataset_source_full_vs_minibatch() {
        let mut rng = Rng::seed_from(4);
        let (obj, _) = planted_regression(30, 6, Tail::Gaussian, Tail::Gaussian, 0.0, &mut rng);
        let mut full = DatasetGradSource {
            obj: obj.clone(),
            batch: 0,
            rng: Rng::seed_from(5),
            idx: Vec::new(),
        };
        let x = vec![0.2f32; 6];
        let mut g1 = vec![0.0f32; 6];
        full.grad(&x, &mut g1);
        let mut want = vec![0.0f32; 6];
        obj.gradient(&x, &mut want);
        assert_eq!(g1, want);
        let mut mini =
            DatasetGradSource { obj, batch: 10, rng: Rng::seed_from(6), idx: Vec::new() };
        let mut g2 = vec![0.0f32; 6];
        mini.grad(&x, &mut g2);
        assert!(g2.iter().all(|v| v.is_finite()));
    }
}
