//! Worker loop: receive the broadcast iterate, evaluate the local
//! (sub)gradient, encode under the worker's own bit budget `⌊n·R_i⌋`,
//! upload through the run's [`WorkerTransport`].
//!
//! The loop owns a [`Workspace`] and recycles message buffers through the
//! run's [`ChannelPools`], so a steady-state round performs zero heap
//! allocations: the gradient buffer, the codec scratch and the wire bytes
//! are all reused round-over-round.

use crate::coordinator::channel::{ChannelError, ChannelPools};
use crate::coordinator::protocol::Upload;
use crate::coordinator::transport::WorkerTransport;
use crate::linalg::rng::Rng;
use crate::quant::{Compressed, Compressor, Workspace};

/// A worker's private gradient source. Implementations: pure-Rust dataset
/// shards ([`DatasetGradSource`]) and PJRT-compiled models (the transformer
/// example builds one over [`crate::runtime::Artifact`]).
pub trait GradSource: Send {
    fn dim(&self) -> usize;
    /// Write a local (mini-batch) subgradient at `x` into `out`; return the
    /// local objective value (metrics side channel).
    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> f32;
}

/// Minibatch gradient source over a private [`DatasetObjective`] shard.
///
/// [`DatasetObjective`]: crate::opt::objectives::DatasetObjective
pub struct DatasetGradSource {
    pub obj: crate::opt::objectives::DatasetObjective,
    /// 0 = full local gradient.
    pub batch: usize,
    pub rng: Rng,
    /// Reused minibatch index buffer (allocation-free steady state);
    /// start with `Vec::new()`.
    pub idx: Vec<usize>,
}

impl GradSource for DatasetGradSource {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        if self.batch == 0 || self.batch >= self.obj.m {
            self.obj.gradient(x, out);
        } else {
            self.rng.sample_indices_into(self.obj.m, self.batch, &mut self.idx);
            self.obj.minibatch_gradient(x, Some(&self.idx), out);
        }
        self.obj.value(x)
    }
}

/// The worker thread body: loops until the downlink closes.
///
/// Buffer recycling protocol: the broadcast's iterate buffer is returned to
/// `pools.iterates` as soon as the gradient is evaluated — *before* the
/// upload is sent — so the server is guaranteed to find `m` parked iterate
/// buffers once it has collected a round's `m` frames. The wire-byte
/// buffer comes from `pools.bytes` (parked there by the server after the
/// previous round's decode).
pub fn worker_loop(
    id: usize,
    source: &mut dyn GradSource,
    compressor: &dyn Compressor,
    transport: &mut dyn WorkerTransport,
    pools: &ChannelPools,
    rng: &mut Rng,
) {
    let n = source.dim();
    let mut g = vec![0.0f32; n];
    let mut ws = Workspace::for_compressor(compressor);
    while let Some(bcast) = transport.recv_broadcast() {
        let local_value = source.grad(&bcast.iterate, &mut g);
        pools.iterates.put(bcast.iterate);
        let mut msg = Compressed {
            n,
            bytes: pools.bytes.get_or(Vec::new),
            payload_bits: 0,
            side_bits: 0,
        };
        compressor.compress_into(&g, rng, &mut ws, &mut msg);
        match transport.upload(Upload { round: bcast.round, worker: id, msg, local_value }) {
            Ok(()) => {}
            Err(ChannelError::OverBudget { payload_bits, budget_bits }) => {
                // A correct compressor never trips this; it is the runtime
                // guard against mis-configured schemes.
                panic!(
                    "worker {id}: compressor '{}' exceeded budget ({payload_bits} > {budget_bits} bits)",
                    compressor.name()
                );
            }
            Err(ChannelError::Disconnected(_)) => break, // server gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Broadcast;
    use crate::coordinator::transport::{self, ServerTransport, TransportKind};
    use crate::data::synthetic::{planted_regression, Tail};
    use crate::quant::ndsc::Ndsc;

    #[test]
    fn worker_responds_to_each_broadcast() {
        let mut rng = Rng::seed_from(1);
        let (obj, _) = planted_regression(20, 8, Tail::Gaussian, Tail::Gaussian, 0.0, &mut rng);
        let mut source =
            DatasetGradSource { obj, batch: 0, rng: Rng::seed_from(2), idx: Vec::new() };
        let comp = Ndsc::hadamard(8, 2.0, &mut rng);
        let (mut server, mut workers) =
            transport::build(&TransportKind::InProc, &[Some(crate::quant::budget_bits(8, 2.0))]);
        let mut wtp = workers.pop().unwrap();
        let pools = server.pools().clone();
        let mut wrng = Rng::seed_from(3);
        let handle = std::thread::spawn(move || {
            worker_loop(7, &mut source, &comp, wtp.as_mut(), &pools, &mut wrng);
        });
        for round in 0..5u64 {
            server.broadcast(0, Broadcast { round, iterate: vec![0.1; 8] }).unwrap();
            let a = server.recv().unwrap();
            assert_eq!(a.at, Some(0), "in-process delivery is instant");
            assert_eq!(a.up.round, round);
            assert_eq!(a.up.worker, 7);
            assert!(a.up.msg.payload_bits <= 16);
            assert!(a.up.local_value.is_finite());
        }
        server.finish();
        handle.join().unwrap();
    }

    #[test]
    fn dataset_source_full_vs_minibatch() {
        let mut rng = Rng::seed_from(4);
        let (obj, _) = planted_regression(30, 6, Tail::Gaussian, Tail::Gaussian, 0.0, &mut rng);
        let mut full = DatasetGradSource {
            obj: obj.clone(),
            batch: 0,
            rng: Rng::seed_from(5),
            idx: Vec::new(),
        };
        let x = vec![0.2f32; 6];
        let mut g1 = vec![0.0f32; 6];
        full.grad(&x, &mut g1);
        let mut want = vec![0.0f32; 6];
        obj.gradient(&x, &mut want);
        assert_eq!(g1, want);
        let mut mini =
            DatasetGradSource { obj, batch: 10, rng: Rng::seed_from(6), idx: Vec::new() };
        let mut g2 = vec![0.0f32; 6];
        mini.grad(&x, &mut g2);
        assert!(g2.iter().all(|v| v.is_finite()));
    }
}
