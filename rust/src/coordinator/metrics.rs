//! Run metrics: per-round loss, traffic, wall-clock; CSV export for the
//! figure harness and EXPERIMENTS.md.
//!
//! The CSV schema lives here **once**: [`CSV_HEADER`] + [`csv_row`] are
//! shared by every per-round consumer — [`RunMetrics::to_csv`] for
//! coordinator runs and [`crate::opt::Trace::to_csv`] for inline engine
//! runs — so a new column (as `participants` was) lands everywhere at
//! the same time.

use std::time::Duration;

/// Header of the shared per-round CSV schema.
pub const CSV_HEADER: &str = "round,value,mean_local_value,payload_bits,participants,wall_us\n";

/// Format one per-round CSV row of the shared schema. Consumers that do
/// not track a column pass `NaN` (local values) or `0` (wall-clock).
pub fn csv_row(
    round: u64,
    value: f32,
    mean_local_value: f32,
    payload_bits: usize,
    participants: usize,
    wall_us: u128,
) -> String {
    format!("{round},{value},{mean_local_value},{payload_bits},{participants},{wall_us}\n")
}

/// One consensus round.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: u64,
    /// Global objective value (server-side eval of the current iterate).
    pub value: f32,
    /// Mean of worker-reported local losses (cheap proxy when the global
    /// objective is expensive to evaluate, e.g. the transformer).
    pub mean_local_value: f32,
    /// Total uplink payload bits this round (all workers).
    pub payload_bits: usize,
    /// Uploads the server actually aggregated this round (`= workers`
    /// under full participation; fewer under k-of-m / deadline policies
    /// or lossy links).
    pub participants: usize,
    pub wall: Duration,
}

/// Full run log.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub rounds: Vec<RoundMetrics>,
    pub total_payload_bits: usize,
    pub total_overhead_bits: usize,
    pub rejected_messages: usize,
    pub final_iterate: Vec<f32>,
}

impl RunMetrics {
    pub fn final_value(&self) -> f32 {
        self.rounds.last().map(|r| r.value).unwrap_or(f32::NAN)
    }

    /// Bits per dimension per worker per round, averaged over the run.
    pub fn mean_rate(&self, n: usize, workers: usize) -> f32 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.total_payload_bits as f32 / (n * workers * self.rounds.len()) as f32
    }

    /// Mean participants per round (the effective `k` of the run).
    pub fn mean_participants(&self) -> f32 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.participants).sum::<usize>() as f32
            / self.rounds.len() as f32
    }

    /// CSV dump:
    /// `round,value,mean_local_value,payload_bits,participants,wall_us`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(CSV_HEADER);
        for r in &self.rounds {
            s.push_str(&csv_row(
                r.round,
                r.value,
                r.mean_local_value,
                r.payload_bits,
                r.participants,
                r.wall.as_micros(),
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Multi-job fleet accounting (the serving layer's view of the budget).
// ---------------------------------------------------------------------------

/// Header of the per-job fleet accounting CSV
/// ([`FleetMetrics::to_csv`]).
pub const FLEET_CSV_HEADER: &str = "job,name,rounds_served,payload_bits,side_bits,bits_per_round\n";

/// Uplink accounting for one job of a multi-job serve fleet
/// ([`crate::serve::fleet::JobServer`]): how many engine rounds the
/// scheduler granted it and what it actually put on the wire. Rows are
/// updated in place every fleet round (plain integer adds — the serve
/// steady state is allocation-free).
#[derive(Clone, Debug, Default)]
pub struct JobBits {
    /// Fleet-assigned job id.
    pub job: u64,
    /// The job's submitted name.
    pub name: String,
    /// Engine rounds the scheduler granted this job.
    pub rounds_served: u64,
    /// Measured uplink payload bits across all served rounds (all the
    /// job's workers).
    pub payload_bits: u64,
    /// Measured side-information bits across all served rounds.
    pub side_bits: u64,
}

/// Aggregate accounting of a serve fleet: the global budget, how much of
/// it was spent, and one [`JobBits`] row per submitted job (parallel to
/// the fleet's slot order; rows persist after a job finishes or is
/// cancelled).
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// The arbitrated global budget (payload bits per fleet round).
    pub budget_bits_per_round: usize,
    /// Fleet rounds executed (scheduler passes, not job rounds).
    pub fleet_rounds: u64,
    /// Total measured payload bits across all jobs and rounds.
    pub spent_payload_bits: u64,
    /// Per-job accounting rows.
    pub jobs: Vec<JobBits>,
}

impl FleetMetrics {
    /// Total engine rounds served across all jobs.
    pub fn served_job_rounds(&self) -> u64 {
        self.jobs.iter().map(|j| j.rounds_served).sum()
    }

    /// Fraction of the cumulative budget actually spent (measured payload
    /// over `budget × fleet_rounds`); 0 when no round has run. Under
    /// deficit-round-robin this is also the scheduler's work-conservation
    /// proxy.
    pub fn utilization(&self) -> f32 {
        let offered = self.budget_bits_per_round as u64 * self.fleet_rounds;
        if offered == 0 {
            return 0.0;
        }
        self.spent_payload_bits as f32 / offered as f32
    }

    /// Per-job CSV in the [`FLEET_CSV_HEADER`] schema.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(FLEET_CSV_HEADER);
        for j in &self.jobs {
            let per_round =
                if j.rounds_served == 0 { 0.0 } else { j.payload_bits as f64 / j.rounds_served as f64 };
            s.push_str(&format!(
                "{},{},{},{},{},{per_round}\n",
                j.job, j.name, j.rounds_served, j.payload_bits, j.side_bits
            ));
        }
        s
    }
}

/// Header of the per-fleet cluster accounting CSV
/// ([`ClusterMetrics::to_csv`]).
pub const CLUSTER_CSV_HEADER: &str =
    "fleet,jobs,served_job_rounds,spent_payload_bits,utilization\n";

/// Aggregate accounting of a multi-fleet cluster
/// ([`crate::serve::cluster::FleetCluster`]): the tenant population
/// broken down by outcome — served (all rounds complete), queued (admitted,
/// still live), rejected (admission refused) and migrated (moved between
/// fleets mid-run) — plus the per-fleet [`FleetMetrics`] snapshots.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// Cluster rounds executed (one concurrent round across all fleets).
    pub cluster_rounds: u64,
    /// Jobs that completed every configured engine round.
    pub served_jobs: u64,
    /// Jobs admitted and still live (running or paused).
    pub queued_jobs: u64,
    /// Submissions refused at admission (invalid or infeasible specs).
    pub rejected_jobs: u64,
    /// Fleet-to-fleet migrations performed.
    pub migrated_jobs: u64,
    /// Grants a work-stealing pool worker executed for a fleet other
    /// than its own (0 under the lockstep executor).
    pub stolen_grants: u64,
    /// Fleets currently taking placements (≤ `fleets.len()`; the
    /// autoscaler moves this between epochs).
    pub active_fleets: u64,
    /// Times the autoscaler resized the active fleet set.
    pub autoscale_events: u64,
    /// Engine rounds granted across the whole cluster.
    pub served_job_rounds: u64,
    /// Measured payload bits spent across the whole cluster.
    pub spent_payload_bits: u64,
    /// Codec-plan cache hits: ladders reused at admission, restore or
    /// migration instead of regrown
    /// ([`crate::serve::plancache::PlanCache`]).
    pub plan_cache_hits: u64,
    /// Codec-plan cache misses (ladder builds routed through the
    /// cache; uncacheable schemes bypass and count in neither column).
    pub plan_cache_misses: u64,
    /// Bytes of immutable plan state the cache currently pins, by true
    /// `resident_bytes` accounting (≤ the configured LRU cap).
    pub plan_cache_resident_bytes: u64,
    /// One accounting snapshot per member fleet.
    pub fleets: Vec<FleetMetrics>,
}

impl ClusterMetrics {
    /// Per-fleet CSV in the [`CLUSTER_CSV_HEADER`] schema (`jobs`
    /// counts that fleet's accounting rows, finished ones included).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(CLUSTER_CSV_HEADER);
        for (i, f) in self.fleets.iter().enumerate() {
            s.push_str(&format!(
                "{i},{},{},{},{}\n",
                f.jobs.len(),
                f.served_job_rounds(),
                f.spent_payload_bits,
                f.utilization()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_csv_has_one_row_per_fleet() {
        let m = ClusterMetrics {
            cluster_rounds: 7,
            served_jobs: 3,
            queued_jobs: 1,
            rejected_jobs: 2,
            migrated_jobs: 1,
            stolen_grants: 5,
            active_fleets: 2,
            autoscale_events: 1,
            served_job_rounds: 9,
            spent_payload_bits: 400,
            plan_cache_hits: 4,
            plan_cache_misses: 2,
            plan_cache_resident_bytes: 1024,
            fleets: vec![FleetMetrics::default(), FleetMetrics::default()],
        };
        let csv = m.to_csv();
        assert!(csv.starts_with(CLUSTER_CSV_HEADER));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("0,"));
        assert!(csv.lines().nth(2).unwrap().starts_with("1,"));
    }

    #[test]
    fn fleet_csv_and_utilization() {
        let m = FleetMetrics {
            budget_bits_per_round: 100,
            fleet_rounds: 4,
            spent_payload_bits: 300,
            jobs: vec![
                JobBits { job: 0, name: "a".into(), rounds_served: 3, payload_bits: 240, side_bits: 12 },
                JobBits { job: 1, name: "b".into(), rounds_served: 2, payload_bits: 60, side_bits: 4 },
            ],
        };
        assert_eq!(m.served_job_rounds(), 5);
        assert!((m.utilization() - 0.75).abs() < 1e-6);
        let csv = m.to_csv();
        assert!(csv.starts_with(FLEET_CSV_HEADER));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("0,a,3,240,12,80"));
        // No rounds offered yet: utilization is defined (0), not NaN.
        assert_eq!(FleetMetrics::default().utilization(), 0.0);
    }

    #[test]
    fn csv_and_rate() {
        let mut m = RunMetrics::default();
        for i in 0..4u64 {
            m.rounds.push(RoundMetrics {
                round: i,
                value: 1.0 / (i + 1) as f32,
                mean_local_value: 0.0,
                payload_bits: 100,
                participants: 2,
                wall: Duration::from_micros(5),
            });
        }
        m.total_payload_bits = 400;
        // n=10, workers=2, 4 rounds -> 400/(10*2*4) = 5 bits/dim
        assert!((m.mean_rate(10, 2) - 5.0).abs() < 1e-6);
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().next().unwrap().contains("participants"));
        assert!((m.final_value() - 0.25).abs() < 1e-6);
        assert!((m.mean_participants() - 2.0).abs() < 1e-6);
    }
}
