//! Decentralized mesh engine: quantized gossip optimization without a
//! server.
//!
//! Every other engine in this repo assumes a star — a coordinator
//! decodes all uploads and broadcasts one consensus iterate. This
//! module drops the server: each node holds its **own** iterate and
//! exchanges *compressed* information with its peer-graph neighbors
//! each round, in the spirit of the decentralized anchors in
//! `PAPERS.md` (Michelusi et al., finite-bit quantization over mesh
//! networks; El Gamal & Lai, randomized quantized coordinate descent)
//! — while reusing this repo's entire codec registry, budget
//! machinery and wire accounting unchanged.
//!
//! # Algorithm (compressed-innovation gossip)
//!
//! Per round `t`, node `i` with iterate `x_i`:
//!
//! 1. queries its local oracle: `g_i = ∇f_i(x_i)`;
//! 2. for each live outgoing link `(i→j)`, encodes the **innovation**
//!    `d = x_i − x̂_{i→j}` (what the receiver does not yet know) with
//!    that link's codec after the link's DEF-style
//!    [`FeedbackMemory`](crate::opt::engine::feedback::FeedbackMemory)
//!    correction, and both endpoints advance their shared estimate
//!    `x̂_{i→j} += q`;
//! 3. takes the difference-form Metropolis gossip step
//!    `x_i += γ Σ_j W_ij (x̂_{j→i} − x̂_{i→j}) − α_t g_i`.
//!
//! Transmitting innovations instead of raw iterates is what makes a
//! finite per-round budget `⌊nR⌋` compatible with *exact* consensus:
//! as the nodes agree, the innovations shrink, and relative-error
//! codecs (the registry zoo) shrink their absolute error with them —
//! the CHOCO-Gossip observation, which Michelusi et al. sharpen to
//! linear convergence under finite bit budgets. With a lossless codec
//! (`fp32`) the estimates track the iterates exactly and the update
//! reduces to textbook Metropolis DGD.
//!
//! The mixing weights `W_ij = 1/(1 + max(deg_i, deg_j))` come from the
//! topology alone ([`MeshGraph`]); the link up/down verdicts, byte
//! accounting ([`upload_wire_bytes`](crate::coordinator::protocol::upload_wire_bytes),
//! bidirectional links charged once per direction) and topology
//! grammar (`ring`, `torus:<r>x<c>`, `random:<p>`, plus the
//! server-rooted shapes as peer graphs) all come from the PR-3
//! transport layer ([`crate::coordinator::transport::simnet`]).

pub mod driver;
pub mod graph;
pub mod metrics;

pub use driver::{link_up, MeshDriver};
pub use graph::MeshGraph;
pub use metrics::{LinkStats, MeshMetrics, MeshRound};

use crate::coordinator::transport::{LinkModel, Topology};
use crate::opt::engine::oracle::ExactGrad;
use crate::opt::engine::schedule::Schedule;
use crate::opt::multi::ShardedProblem;
use crate::quant::registry::CompressorSpec;

/// Salt for per-directed-edge codec construction streams.
pub(crate) const EDGE_BUILD_SALT: u64 = 0xB111_DC0D;
/// Salt for per-round, per-directed-edge dither streams.
pub(crate) const EDGE_CODEC_SALT: u64 = 0xD17E_35A1;
/// Salt for per-round, per-edge link up/down verdicts.
pub(crate) const LINK_SALT: u64 = 0x11AC_E550;
/// Salt for per-node oracle RNG forks.
pub(crate) const NODE_SALT: u64 = 0x40DE_5EED;

/// Full description of a mesh run. Plain fields; [`MeshConfig::new`]
/// fills sensible defaults for the knobs most runs leave alone.
#[derive(Clone, Debug)]
pub struct MeshConfig {
    /// Node count `m` (one oracle/shard per node).
    pub nodes: usize,
    /// Problem dimension.
    pub n: usize,
    /// Peer-graph shape (validated against `nodes`).
    pub topology: Topology,
    /// Codec scheme instantiated on every directed link.
    pub scheme: CompressorSpec,
    /// Per-message budget rate `R` (bits per dimension).
    pub r: f32,
    /// Gossip (consensus) step `γ ∈ (0, 1]`. `1` is exact-DGD
    /// aggressive; lossy codecs want headroom (default `0.5`).
    pub gamma: f32,
    /// Gradient step schedule `α_t`.
    pub schedule: Schedule,
    /// Rounds to run.
    pub rounds: usize,
    /// Master seed: fixes the random-graph overlay, all codec frames,
    /// all dither streams and the link drop schedule.
    pub seed: u64,
    /// Delay/loss model applied to every mesh link; `drop_prob` drives
    /// the pause-on-drop path.
    pub link: LinkModel,
    /// Per-edge DEF error feedback on the innovation codewords.
    pub feedback: bool,
    /// Scoped worker threads for the per-round phases (traces are
    /// bit-identical for any value).
    pub threads: usize,
}

impl MeshConfig {
    /// A config with the common defaults: `γ = 0.5`, constant step
    /// `0.05`, 400 rounds, ideal links, feedback on, single-threaded.
    pub fn new(
        nodes: usize,
        n: usize,
        topology: Topology,
        scheme: CompressorSpec,
        r: f32,
        seed: u64,
    ) -> Self {
        MeshConfig {
            nodes,
            n,
            topology,
            scheme,
            r,
            gamma: 0.5,
            schedule: Schedule::Constant(0.05),
            rounds: 400,
            seed,
            link: LinkModel::IDEAL,
            feedback: true,
            threads: 1,
        }
    }

    /// Validate the whole config — topology node count included — as a
    /// config error, never a panic.
    pub fn validate(&self) -> Result<(), String> {
        self.topology.validate(self.nodes)?;
        if self.n == 0 {
            return Err("mesh dimension n must be positive".into());
        }
        if !self.scheme.is_feasible(self.n, self.r) {
            return Err(format!(
                "scheme {} cannot honor the budget at n = {}, R = {}",
                self.scheme.name(),
                self.n,
                self.r
            ));
        }
        if !(self.gamma > 0.0 && self.gamma <= 1.0) {
            return Err(format!("gossip step gamma must lie in (0, 1], got {}", self.gamma));
        }
        if self.rounds == 0 {
            return Err("mesh runs need at least one round".into());
        }
        if self.threads == 0 {
            return Err("mesh threads must be positive".into());
        }
        Ok(())
    }
}

/// Run a full mesh job with one objective shard per node (exact local
/// gradients), all nodes starting from `x = 0`; the traced objective
/// is the global average `f(x̄) = (1/m) Σ f_i(x̄)`.
pub fn run_sharded(cfg: MeshConfig, prob: &ShardedProblem) -> Result<MeshMetrics, String> {
    if prob.m() != cfg.nodes {
        return Err(format!(
            "problem has {} shards but the mesh has {} nodes",
            prob.m(),
            cfg.nodes
        ));
    }
    if prob.n != cfg.n {
        return Err(format!("problem dimension {} does not match n = {}", prob.n, cfg.n));
    }
    let oracles: Vec<ExactGrad<'_>> = prob.shards.iter().map(|s| ExactGrad { obj: s }).collect();
    let x0 = vec![0.0f32; cfg.n];
    let mut drv = MeshDriver::new(cfg, oracles, &x0)?;
    Ok(drv.run(&|x| prob.value(x)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_catches_degenerate_shapes_and_knobs() {
        let ok = MeshConfig::new(4, 16, Topology::Ring, CompressorSpec::Fp32, 32.0, 1);
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.nodes = 2;
        assert!(bad.validate().is_err(), "ring below minimum size");
        let mut bad = ok.clone();
        bad.topology = Topology::Torus { rows: 3, cols: 3 };
        assert!(bad.validate().is_err(), "torus must tile the node count");
        let mut bad = ok.clone();
        bad.r = 1.0;
        assert!(bad.validate().is_err(), "fp32 needs R >= 32");
        let mut bad = ok.clone();
        bad.gamma = 0.0;
        assert!(bad.validate().is_err(), "gamma must be positive");
        let mut bad = ok;
        bad.rounds = 0;
        assert!(bad.validate().is_err(), "at least one round");
    }
}
