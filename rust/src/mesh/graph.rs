//! Peer-graph structure and Metropolis mixing weights.
//!
//! [`MeshGraph`] turns a [`Topology`] into the indexed adjacency the
//! driver's hot loop needs: sorted neighbor lists, the undirected edge
//! id behind every `(node, slot)`, the reverse slot (where the
//! neighbor keeps its state for the opposite direction), and the
//! Metropolis–Hastings mixing weights
//!
//! ```text
//!   W_ij = 1 / (1 + max(deg_i, deg_j))   for each edge {i, j},
//!   W_ii = 1 − Σ_{j ∈ N_i} W_ij,
//! ```
//!
//! which are symmetric and doubly stochastic for **any** connected
//! graph, using only local degree information — the standard choice in
//! the decentralized literature (Michelusi et al.; CHOCO-Gossip). The
//! self-weight is strictly positive (each row sums at most
//! `deg_i / (1 + deg_i)` over the neighbors), so `W` is also positive
//! semi-definite enough in practice for gossip steps `γ ≤ 1`.

use crate::coordinator::transport::Topology;

/// Indexed peer graph: adjacency, edge ids and Metropolis weights.
#[derive(Clone, Debug)]
pub struct MeshGraph {
    /// Node count.
    pub m: usize,
    /// Undirected edges `(i, j)`, `i < j`, sorted — the id space for
    /// per-link accounting and link up/down verdicts.
    pub edges: Vec<(usize, usize)>,
    /// Sorted neighbor list per node.
    pub neighbors: Vec<Vec<usize>>,
    /// Metropolis weight per `(node, slot)`, aligned with `neighbors`.
    pub weights: Vec<Vec<f32>>,
    /// Undirected edge id per `(node, slot)`.
    pub edge_of: Vec<Vec<usize>>,
    /// For `(node i, slot k)` with neighbor `j`: the slot of `i` in
    /// `j`'s neighbor list (where `j` keeps the `j→i` direction).
    pub rev_slot: Vec<Vec<usize>>,
}

impl MeshGraph {
    /// Build the indexed graph for `topology` over `m` nodes.
    /// `seed` fixes the `random:<p>` overlay; other shapes ignore it.
    pub fn build(topology: Topology, m: usize, seed: u64) -> Result<MeshGraph, String> {
        topology.validate(m)?;
        let edges = topology.mesh_edges(m, seed);
        let mut neighbors = vec![Vec::new(); m];
        for &(a, b) in &edges {
            neighbors[a].push(b);
            neighbors[b].push(a);
        }
        for list in &mut neighbors {
            list.sort_unstable();
        }
        let deg: Vec<usize> = neighbors.iter().map(|l| l.len()).collect();
        let mut weights = Vec::with_capacity(m);
        let mut edge_of = Vec::with_capacity(m);
        let mut rev_slot = Vec::with_capacity(m);
        for i in 0..m {
            let mut w_row = Vec::with_capacity(deg[i]);
            let mut e_row = Vec::with_capacity(deg[i]);
            let mut r_row = Vec::with_capacity(deg[i]);
            for &j in &neighbors[i] {
                w_row.push(1.0 / (1 + deg[i].max(deg[j])) as f32);
                let key = (i.min(j), i.max(j));
                let e = edges.binary_search(&key).expect("edge from adjacency");
                e_row.push(e);
                let r = neighbors[j].binary_search(&i).expect("adjacency is symmetric");
                r_row.push(r);
            }
            weights.push(w_row);
            edge_of.push(e_row);
            rev_slot.push(r_row);
        }
        Ok(MeshGraph { m, edges, neighbors, weights, edge_of, rev_slot })
    }

    /// Node degree.
    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    /// The Metropolis self-weight `W_ii = 1 − Σ_j W_ij` (the
    /// difference-form gossip update never multiplies by it, but it
    /// completes the doubly-stochastic picture for reporting/tests).
    pub fn self_weight(&self, i: usize) -> f32 {
        1.0 - self.weights[i].iter().sum::<f32>()
    }

    /// Globally unique directed-edge id for `(node, slot)`: undirected
    /// edge id doubled, plus one for the high→low direction. Seeds the
    /// per-direction codec dither streams.
    pub fn directed_id(&self, i: usize, slot: usize) -> usize {
        2 * self.edge_of[i][slot] + usize::from(i > self.neighbors[i][slot])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metropolis_weights_are_symmetric_and_doubly_stochastic() {
        for (topo, m) in [
            (Topology::Ring, 6),
            (Topology::Torus { rows: 3, cols: 3 }, 9),
            (Topology::random(0.5), 8),
            (Topology::Star, 5),
        ] {
            let g = MeshGraph::build(topo, m, 42).unwrap();
            for i in 0..m {
                // Row sum with the self-weight is exactly 1 by
                // construction; the neighbor mass must leave it positive.
                let row: f32 = g.weights[i].iter().sum();
                assert!(row < 1.0, "self-weight must stay positive");
                assert!(g.self_weight(i) > 0.0);
                for (slot, &j) in g.neighbors[i].iter().enumerate() {
                    let back = g.rev_slot[i][slot];
                    assert_eq!(g.neighbors[j][back], i, "rev_slot must point back");
                    assert_eq!(
                        g.weights[i][slot].to_bits(),
                        g.weights[j][back].to_bits(),
                        "W must be symmetric bit-for-bit"
                    );
                    assert_eq!(g.edge_of[i][slot], g.edge_of[j][back]);
                }
            }
        }
    }

    #[test]
    fn directed_ids_cover_both_directions_of_every_edge() {
        let g = MeshGraph::build(Topology::Ring, 5, 0).unwrap();
        let mut seen = vec![false; 2 * g.edges.len()];
        for i in 0..g.m {
            for slot in 0..g.degree(i) {
                let id = g.directed_id(i, slot);
                assert!(!seen[id], "directed ids must be unique");
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every direction must appear");
    }

    #[test]
    fn degenerate_shapes_surface_the_config_error() {
        assert!(MeshGraph::build(Topology::Ring, 2, 0).is_err());
        assert!(MeshGraph::build(Topology::Torus { rows: 3, cols: 3 }, 8, 0).is_err());
    }
}
