//! Mesh run metrics: the consensus trajectory, the objective at the
//! node average, and exact per-link wire accounting.
//!
//! The wire contract matches the coordinator path bit for bit: every
//! delivered directed message is charged
//! [`upload_wire_bytes`](crate::coordinator::protocol::upload_wire_bytes)
//! at the moment it leaves its sender, so a bidirectional link is
//! counted **twice per round** (once per direction) — exactly what the
//! paper's per-node budget `⌊nR⌋` doubles to on peer-to-peer links.

/// One round of the mesh trace.
#[derive(Clone, Debug)]
pub struct MeshRound {
    /// 0-based round index.
    pub round: usize,
    /// Consensus distance `max_i ‖x_i − x̄‖₂` after the round.
    pub consensus: f32,
    /// Global objective at the node average `f(x̄)`.
    pub value: f32,
    /// Wire bytes shipped this round, all delivered directions summed.
    pub wire_bytes: u64,
}

/// Per-undirected-link accounting over a whole run.
#[derive(Clone, Debug)]
pub struct LinkStats {
    /// Lower endpoint.
    pub a: usize,
    /// Higher endpoint.
    pub b: usize,
    /// Total wire bytes — both directions, each delivered message
    /// charged `upload_wire_bytes` exactly.
    pub bytes: u64,
    /// Delivered directed messages.
    pub delivered: u64,
    /// Directed messages suppressed by a down round (pause-on-drop).
    pub dropped: u64,
}

/// Full metrics of a mesh run.
#[derive(Clone, Debug, Default)]
pub struct MeshMetrics {
    /// Per-round trace, in round order.
    pub rounds: Vec<MeshRound>,
    /// Per-link wire accounting, indexed like `MeshGraph::edges`.
    pub per_link: Vec<LinkStats>,
    /// Total outgoing wire bits per node.
    pub node_wire_bits: Vec<u64>,
    /// Consensus distance after the last round.
    pub final_consensus: f32,
    /// Objective at the node average after the last round.
    pub final_value: f32,
    /// The node average after the last round.
    pub final_mean: Vec<f32>,
}

impl MeshMetrics {
    /// Total wire bytes over all links (= Σ node bits / 8, since every
    /// byte is charged to exactly one sending node and one link).
    pub fn total_wire_bytes(&self) -> u64 {
        self.per_link.iter().map(|l| l.bytes).sum()
    }
}
