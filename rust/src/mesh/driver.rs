//! The lockstep gossip driver: all nodes advanced round by round on
//! scoped threads, with a deterministic, deadlock-free exchange.
//!
//! # Round structure
//!
//! Each round has two parallel phases separated by one barrier (the
//! scoped-thread join), plus a sequential accounting pass:
//!
//! 1. **send** — every node queries its local oracle at its own
//!    iterate, then, for each *live* outgoing link, encodes the
//!    innovation `x_i − x̂_{i→j}` (after the edge's
//!    [`FeedbackMemory::pre_encode`]) with that directed link's codec,
//!    immediately decodes it (shared randomness makes the sender-side
//!    decode bit-identical to the receiver's), advances its replica
//!    `x̂_{i→j} += q`, and posts `q` plus the
//!    [`upload_wire_bytes`]-charged byte count to its outbox;
//! 2. **mix** — every node folds the posted codewords of its live
//!    in-links into its estimates `x̂_{j→i} += q` and takes the
//!    difference-form Metropolis gossip step
//!    `x_i += γ Σ_j W_ij (x̂_{j→i} − x̂_{i→j}) − α_t g_i`.
//!
//! Because `x̂_{j→i}` (kept by `i`) and `x̂_{i→j}` (kept by `j`) are
//! replicas advanced by the same codewords on the same live rounds,
//! the pairwise gossip terms cancel exactly and the node average obeys
//! `x̄ += −(α_t/m) Σ g_i` — compression never leaks mass. With a
//! lossless codec the estimates equal the iterates after one exchange
//! and the update reduces to exact Metropolis DGD.
//!
//! # Determinism
//!
//! Every random draw comes from a stream that is pure in its owner:
//! per-node oracle streams (forked once from the config seed), per-
//! directed-edge dither streams reseeded per round from
//! `round_rank(seed, round, directed_id)`, and per-edge link verdicts
//! from the PR-3 SimNet [`delivery`] model. No draw depends on thread
//! interleaving, every floating-point reduction runs in fixed node
//! order on one thread, and the phase barrier is the only
//! synchronization — traces are bit-identical across repeated runs
//! *and* across `threads` settings (`rust/tests/test_mesh.rs`).
//!
//! # Pause-on-drop
//!
//! A link's up/down verdict is drawn once per round per *undirected*
//! edge, so both directions pause together (the FSPDA-style rule):
//! no encode, no dither draw consumed from the edge stream, no bytes
//! charged, and the edge's feedback memory and estimate replicas stay
//! untouched on both endpoints of the paused link.

use crate::coordinator::protocol::upload_wire_bytes;
use crate::coordinator::transport::round_rank;
use crate::coordinator::transport::simnet::{delivery, LinkModel};
use crate::linalg::rng::Rng;
use crate::opt::engine::feedback::{DefFeedback, FeedbackMemory, NoFeedback};
use crate::opt::engine::oracle::Oracle;
use crate::opt::engine::schedule::StepSchedule;
use crate::quant::{Compressed, Compressor, Workspace};

use super::graph::MeshGraph;
use super::metrics::{LinkStats, MeshMetrics, MeshRound};
use super::{MeshConfig, EDGE_BUILD_SALT, EDGE_CODEC_SALT, LINK_SALT, NODE_SALT};

/// Pure per-`(seed, round, edge)` link verdict shared by both
/// directions of undirected edge `edge`: one hop of the PR-3 SimNet
/// [`delivery`] model decides whether the edge is up this round. Both
/// endpoints evaluate the same verdict, so a down edge pauses
/// symmetrically.
pub fn link_up(seed: u64, round: u64, edge: usize, link: &LinkModel) -> bool {
    delivery(seed ^ LINK_SALT, round, edge, 1, link, 0).is_some()
}

/// One node's private state. Codecs, feedback memories and estimate
/// replicas are indexed by the node's neighbor *slot* (position in the
/// sorted neighbor list).
struct MeshNode {
    x: Vec<f32>,
    grad: Vec<f32>,
    rng: Rng,
    ws: Workspace,
    msg: Compressed,
    /// Innovation scratch (the vector handed to the encoder).
    ubuf: Vec<f32>,
    /// Decode scratch in the send phase, mix accumulator afterwards.
    qbuf: Vec<f32>,
    /// One codec per outgoing directed link.
    codecs: Vec<Box<dyn Compressor>>,
    /// One feedback memory per outgoing directed link.
    fb: Vec<Box<dyn FeedbackMemory>>,
    /// `x̂_{i→slot}`: replica of the receiver's estimate of me.
    est_out: Vec<Vec<f32>>,
    /// `x̂_{slot→i}`: my estimate of each neighbor.
    est_in: Vec<Vec<f32>>,
}

/// What a node posts per outgoing link per round.
#[derive(Clone)]
struct OutSlot {
    /// Decoded codeword the receiver applies to its estimate.
    q: Vec<f32>,
    /// `upload_wire_bytes` of the message, 0 on a paused round.
    bytes: u64,
    /// Whether the link was up this round.
    up: bool,
}

/// The decentralized gossip engine: owns all node state and advances
/// the whole mesh one lockstep round at a time.
pub struct MeshDriver<O: Oracle + Send> {
    cfg: MeshConfig,
    graph: MeshGraph,
    nodes: Vec<MeshNode>,
    oracles: Vec<O>,
    outboxes: Vec<Vec<OutSlot>>,
    round: usize,
    link_bytes: Vec<u64>,
    link_delivered: Vec<u64>,
    link_dropped: Vec<u64>,
    node_bits: Vec<u64>,
    trace: Vec<MeshRound>,
}

impl<O: Oracle + Send> MeshDriver<O> {
    /// Build the mesh: one oracle per node, all nodes starting at `x0`.
    /// Validates the config (including the topology's node count) and
    /// grows one codec + one feedback memory per directed link.
    pub fn new(cfg: MeshConfig, oracles: Vec<O>, x0: &[f32]) -> Result<Self, String> {
        cfg.validate()?;
        if oracles.len() != cfg.nodes {
            return Err(format!(
                "mesh needs one oracle per node: got {} oracles for {} nodes",
                oracles.len(),
                cfg.nodes
            ));
        }
        if let Some(o) = oracles.iter().find(|o| o.dim() != cfg.n) {
            return Err(format!("oracle dimension {} does not match n = {}", o.dim(), cfg.n));
        }
        if x0.len() != cfg.n {
            return Err(format!("x0 has dimension {}, expected {}", x0.len(), cfg.n));
        }
        let graph = MeshGraph::build(cfg.topology, cfg.nodes, cfg.seed)?;
        let mut nodes = Vec::with_capacity(cfg.nodes);
        let mut outboxes = Vec::with_capacity(cfg.nodes);
        for i in 0..cfg.nodes {
            let deg = graph.degree(i);
            let mut codecs: Vec<Box<dyn Compressor>> = Vec::with_capacity(deg);
            let mut fb: Vec<Box<dyn FeedbackMemory>> = Vec::with_capacity(deg);
            for slot in 0..deg {
                // Each directed link owns its codec, built from a
                // stream pure in (seed, directed edge id): shared
                // randomness between the endpoints by construction.
                let dir = graph.directed_id(i, slot);
                let mut crng =
                    Rng::seed_from(round_rank(cfg.seed ^ EDGE_BUILD_SALT, dir as u64, 0));
                codecs.push(cfg.scheme.build(cfg.n, cfg.r, &mut crng));
                fb.push(if cfg.feedback {
                    Box::new(DefFeedback::new(1, cfg.n)) as Box<dyn FeedbackMemory>
                } else {
                    Box::new(NoFeedback)
                });
            }
            let ws = codecs
                .first()
                .map_or_else(Workspace::new, |c| Workspace::for_compressor(c.as_ref()));
            nodes.push(MeshNode {
                x: x0.to_vec(),
                grad: vec![0.0; cfg.n],
                rng: Rng::seed_from(cfg.seed ^ NODE_SALT).fork(i as u64),
                ws,
                msg: Compressed::empty(cfg.n),
                ubuf: vec![0.0; cfg.n],
                qbuf: vec![0.0; cfg.n],
                codecs,
                fb,
                est_out: vec![vec![0.0; cfg.n]; deg],
                est_in: vec![vec![0.0; cfg.n]; deg],
            });
            outboxes.push(vec![OutSlot { q: vec![0.0; cfg.n], bytes: 0, up: false }; deg]);
        }
        let e = graph.edges.len();
        Ok(MeshDriver {
            graph,
            nodes,
            oracles,
            outboxes,
            round: 0,
            link_bytes: vec![0; e],
            link_delivered: vec![0; e],
            link_dropped: vec![0; e],
            node_bits: vec![0; cfg.nodes],
            trace: Vec::with_capacity(cfg.rounds + 1),
            cfg,
        })
    }

    /// Advance one lockstep round. `value` evaluates the *global*
    /// objective at the node average for the trace record.
    pub fn step(&mut self, value: &dyn Fn(&[f32]) -> f32) {
        let round = self.round as u64;
        let alpha = self.cfg.schedule.step(self.round);
        // One verdict per undirected edge, shared by both directions.
        let up: Vec<bool> = (0..self.graph.edges.len())
            .map(|e| link_up(self.cfg.seed, round, e, &self.cfg.link))
            .collect();
        let threads = self.cfg.threads.max(1).min(self.nodes.len());
        let chunk = self.nodes.len().div_ceil(threads);

        let cfg = &self.cfg;
        let graph = &self.graph;
        {
            // Phase 1 (send): each thread owns a disjoint node range
            // plus the matching outbox range; nothing else is written.
            let up = &up[..];
            let nodes = &mut self.nodes;
            let oracles = &mut self.oracles;
            let outboxes = &mut self.outboxes;
            if threads == 1 {
                for (i, ((node, oracle), out)) in
                    nodes.iter_mut().zip(oracles.iter_mut()).zip(outboxes.iter_mut()).enumerate()
                {
                    phase_send(cfg, graph, up, round, i, node, oracle, out);
                }
            } else {
                std::thread::scope(|s| {
                    let mut base = 0usize;
                    for ((nc, oc), xc) in nodes
                        .chunks_mut(chunk)
                        .zip(oracles.chunks_mut(chunk))
                        .zip(outboxes.chunks_mut(chunk))
                    {
                        let b = base;
                        base += nc.len();
                        s.spawn(move || {
                            for (k, ((node, oracle), out)) in
                                nc.iter_mut().zip(oc.iter_mut()).zip(xc.iter_mut()).enumerate()
                            {
                                phase_send(cfg, graph, up, round, b + k, node, oracle, out);
                            }
                        });
                    }
                });
            }
        }
        {
            // Phase 2 (mix): reads only the outboxes written above
            // (the scope join is the barrier) and each thread's own
            // node range.
            let up = &up[..];
            let nodes = &mut self.nodes;
            let outboxes = &self.outboxes[..];
            if threads == 1 {
                for (i, node) in nodes.iter_mut().enumerate() {
                    phase_mix(cfg, graph, up, outboxes, alpha, i, node);
                }
            } else {
                std::thread::scope(|s| {
                    let mut base = 0usize;
                    for nc in nodes.chunks_mut(chunk) {
                        let b = base;
                        base += nc.len();
                        s.spawn(move || {
                            for (k, node) in nc.iter_mut().enumerate() {
                                phase_mix(cfg, graph, up, outboxes, alpha, b + k, node);
                            }
                        });
                    }
                });
            }
        }
        // Sequential accounting in fixed node/slot order, so byte
        // tallies and the f32 reductions are thread-count independent.
        let mut round_bytes = 0u64;
        for i in 0..self.graph.m {
            for slot in 0..self.graph.degree(i) {
                let e = self.graph.edge_of[i][slot];
                let o = &self.outboxes[i][slot];
                if o.up {
                    self.link_bytes[e] += o.bytes;
                    self.link_delivered[e] += 1;
                    self.node_bits[i] += 8 * o.bytes;
                    round_bytes += o.bytes;
                } else {
                    self.link_dropped[e] += 1;
                }
            }
        }
        let mean = self.node_mean();
        let mut consensus = 0.0f32;
        for node in &self.nodes {
            let mut d2 = 0.0f32;
            for k in 0..self.cfg.n {
                let d = node.x[k] - mean[k];
                d2 += d * d;
            }
            consensus = consensus.max(d2.sqrt());
        }
        self.trace.push(MeshRound {
            round: self.round,
            consensus,
            value: value(&mean),
            wire_bytes: round_bytes,
        });
        self.round += 1;
    }

    /// Run the configured number of rounds and return the metrics.
    pub fn run(&mut self, value: &dyn Fn(&[f32]) -> f32) -> MeshMetrics {
        for _ in 0..self.cfg.rounds {
            self.step(value);
        }
        self.metrics()
    }

    /// Metrics snapshot: the trace so far plus the per-link accounting.
    pub fn metrics(&self) -> MeshMetrics {
        let last = self.trace.last();
        MeshMetrics {
            rounds: self.trace.clone(),
            per_link: self
                .graph
                .edges
                .iter()
                .enumerate()
                .map(|(e, &(a, b))| LinkStats {
                    a,
                    b,
                    bytes: self.link_bytes[e],
                    delivered: self.link_delivered[e],
                    dropped: self.link_dropped[e],
                })
                .collect(),
            node_wire_bits: self.node_bits.clone(),
            final_consensus: last.map_or(0.0, |r| r.consensus),
            final_value: last.map_or(0.0, |r| r.value),
            final_mean: self.node_mean(),
        }
    }

    /// The node average `x̄`, reduced in fixed node order.
    pub fn node_mean(&self) -> Vec<f32> {
        let mut mean = vec![0.0f32; self.cfg.n];
        for node in &self.nodes {
            for k in 0..self.cfg.n {
                mean[k] += node.x[k];
            }
        }
        let inv = 1.0 / self.graph.m as f32;
        for v in &mut mean {
            *v *= inv;
        }
        mean
    }

    /// The config this driver runs.
    pub fn cfg(&self) -> &MeshConfig {
        &self.cfg
    }

    /// The indexed peer graph.
    pub fn graph(&self) -> &MeshGraph {
        &self.graph
    }

    /// Rounds completed so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Node `i`'s current iterate.
    pub fn node_x(&self, i: usize) -> &[f32] {
        &self.nodes[i].x
    }

    /// Snapshot of the feedback memory on `node`'s `slot`-th outgoing
    /// link (via [`FeedbackMemory::save_state`]).
    pub fn edge_feedback_state(&self, node: usize, slot: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.nodes[node].fb[slot].save_state(&mut out);
        out
    }

    /// `node`'s replica of what its `slot`-th neighbor believes about
    /// `node`'s iterate (`x̂_{node→neighbor}`).
    pub fn estimate_out(&self, node: usize, slot: usize) -> &[f32] {
        &self.nodes[node].est_out[slot]
    }
}

/// Send phase for node `i`: local gradient, then one encoded
/// innovation per live outgoing link.
#[allow(clippy::too_many_arguments)]
fn phase_send<O: Oracle>(
    cfg: &MeshConfig,
    graph: &MeshGraph,
    up: &[bool],
    round: u64,
    i: usize,
    node: &mut MeshNode,
    oracle: &mut O,
    out: &mut [OutSlot],
) {
    oracle.query(&node.x, &mut node.rng, &mut node.grad);
    for slot in 0..graph.neighbors[i].len() {
        if !up[graph.edge_of[i][slot]] {
            // Pause-on-drop: no encode, no dither draw, no bytes, and
            // the edge's memory and replicas stay untouched.
            out[slot].up = false;
            out[slot].bytes = 0;
            continue;
        }
        // Innovation: the part of x_i the receiver's estimate lacks.
        for k in 0..node.x.len() {
            node.ubuf[k] = node.x[k] - node.est_out[slot][k];
        }
        node.fb[slot].pre_encode(0, &mut node.ubuf);
        let dir = graph.directed_id(i, slot);
        let mut erng = Rng::seed_from(round_rank(cfg.seed ^ EDGE_CODEC_SALT, round, dir));
        node.codecs[slot].compress_into(&node.ubuf, &mut erng, &mut node.ws, &mut node.msg);
        node.codecs[slot].decompress_into(&node.msg, &mut node.ws, &mut node.qbuf);
        node.fb[slot].post_decode(0, &node.qbuf, &node.ubuf);
        // The sender-side replica advances exactly as the receiver's
        // copy will in the mix phase.
        for k in 0..node.x.len() {
            node.est_out[slot][k] += node.qbuf[k];
        }
        out[slot].q.copy_from_slice(&node.qbuf);
        out[slot].bytes = upload_wire_bytes(&node.msg) as u64;
        out[slot].up = true;
    }
}

/// Mix phase for node `i`: fold live in-link codewords into the
/// estimates, then the gossip + gradient step.
fn phase_mix(
    cfg: &MeshConfig,
    graph: &MeshGraph,
    up: &[bool],
    outboxes: &[Vec<OutSlot>],
    alpha: f32,
    i: usize,
    node: &mut MeshNode,
) {
    let n = node.x.len();
    for slot in 0..graph.neighbors[i].len() {
        if !up[graph.edge_of[i][slot]] {
            continue;
        }
        let j = graph.neighbors[i][slot];
        let q = &outboxes[j][graph.rev_slot[i][slot]].q;
        for k in 0..n {
            node.est_in[slot][k] += q[k];
        }
    }
    node.qbuf.fill(0.0);
    // Difference-form Metropolis gossip over the live links; paused
    // links contribute nothing this round (FSPDA-style). The pairwise
    // terms cancel across each edge, so the node average is preserved.
    for slot in 0..graph.neighbors[i].len() {
        if !up[graph.edge_of[i][slot]] {
            continue;
        }
        let w = graph.weights[i][slot];
        for k in 0..n {
            node.qbuf[k] += w * (node.est_in[slot][k] - node.est_out[slot][k]);
        }
    }
    for k in 0..n {
        node.x[k] += cfg.gamma * node.qbuf[k] - alpha * node.grad[k];
    }
}
