//! `repro` — the kashinflow CLI.
//!
//! ```text
//! repro <command> [--quick] [key=value ...]
//! repro help     # the full subcommand list (the `COMMANDS` const — the
//!                # single source the usage text and this doc defer to)
//! ```
//!
//! Highlights: `figures` regenerates every table/figure, `schemes`
//! prints the registry zoo at one `(n, R)`, `net` sweeps SimNet
//! topology × budget × drop, `mesh` sweeps the serverless gossip engine
//! (peer topology × scheme × R × drop, with per-link byte accounting),
//! `serve` sweeps the multi-job serving layer
//! (jobs × global budget × scheduler policy, a mid-run
//! pause/resume/cancel drill, and a ≥1000-tenant multi-fleet cluster
//! pass with live migration), `train` runs the distributed coordinator
//! on a planted problem.
//!
//! `train` keys: n, workers, r (scalar or per-worker `r=0.5,1,2,4`),
//! scheme, frame, rounds, step, batch, radius, seed, part
//! (full|k:<n>|deadline:<µs>), transport (inproc|sim|recorded:<path>) and
//! the SimNet knobs topo/lat/jitter/drop/bw/net-seed (see
//! coordinator::config). Example:
//! `repro train n=116 workers=4 r=0.5 scheme=ndsc-dith rounds=300 \
//!    transport=sim topo=chain drop=0.1 part=k:3`

use std::io::Write;

use kashinflow::coordinator::config::RunConfig;
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::exp;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::engine::driver::run_config;
use kashinflow::opt::multi::ShardedProblem;
use kashinflow::opt::objectives::Loss;
use kashinflow::quant::Compressor;

/// Every subcommand, in `usage`/help order — one list so the help text
/// and the unknown-command error can never go stale against `main`'s
/// dispatch again. (A plain multi-line literal: `\`-continuations would
/// strip the indentation.)
const COMMANDS: &str = "  figures                 every table/figure below in sequence
  table1                  measured scheme comparison (bits, error, time)
  fig1a fig1b fig1c fig1d smooth & strongly-convex experiments
  fig2ab fig2cd           DQ-PSGD SVM experiments
  fig3a fig3b fig5 fig6   multi-worker experiments (3b needs artifacts)
  fig8|fig9 fig11|fig12   Appendix-N lambda studies
  ablation-ef ablation-lambda ablation-dqgd
  schemes                 print the registry zoo at (n, R)
  net                     SimNet topology x budget x drop sweep
  mesh                    decentralized gossip sweep (topology x scheme x R x drop)
  serve                   multi-job serving sweep (jobs x budget x policy x fleets)
  train                   distributed run on a planted problem
  train-transformer       federated transformer (needs artifacts)
  help                    this text";

fn print_usage(out: &mut dyn std::io::Write) {
    let _ = writeln!(out, "usage: repro <command> [--quick] [key=value ...]");
    let _ = writeln!(out, "commands:\n{COMMANDS}");
    let _ = writeln!(out, "see `rust/src/main.rs` docs for the train/net key=value grammar");
}

fn usage() -> ! {
    print_usage(&mut std::io::stderr());
    std::process::exit(2);
}

/// `repro schemes [n=..] [r=..]` — enumerate the registry at one `(n, R)`:
/// name, feasibility under the `⌊nR⌋` wire contract, measured payload,
/// the **exact uplink wire bytes** one framed message occupies
/// (payload + side info + upload header, from the same accounting the
/// budget enforcement uses), and unbiasedness flag of every spec.
fn run_schemes(args: &[String]) {
    let mut n = 1024usize;
    let mut r = 3.0f32;
    for a in args {
        match a.split_once('=') {
            Some(("n", v)) => n = v.parse().unwrap_or(n),
            Some(("r", v)) => r = v.parse().unwrap_or(r),
            _ => {
                eprintln!("schemes: expected n=.. or r=.., got '{a}'");
                std::process::exit(2);
            }
        }
    }
    let budget = kashinflow::quant::budget_bits(n, r);
    let mut rng = Rng::seed_from(0x5EED);
    println!("registry zoo at n={n}, R={r} (budget {budget} payload bits/message):");
    println!(
        "{:<16} {:>8} {:>10} {:>14} {:>12} {:>10} {:>10}",
        "spec", "dim", "feasible", "payload-bits", "bits/dim", "wire-bytes", "unbiased"
    );
    for spec in kashinflow::quant::registry::all_specs() {
        // Dense-frame schemes are built at a capped dimension so that
        // `repro schemes n=131072` (transformer scale) stays instant.
        let dim = kashinflow::quant::registry::dense_frame_dim_cap(&spec, n);
        if !spec.is_feasible(dim, r) {
            println!(
                "{:<16} {:>8} {:>10} {:>14} {:>12} {:>10} {:>10}",
                spec.name(),
                dim,
                "no",
                "-",
                "-",
                "-",
                "-"
            );
            continue;
        }
        let c = spec.build(dim, r, &mut rng);
        let y: Vec<f32> = (0..dim).map(|_| rng.gaussian_cubed()).collect();
        let msg = c.compress(&y, &mut rng);
        println!(
            "{:<16} {:>8} {:>10} {:>14} {:>12.3} {:>10} {:>10}",
            spec.name(),
            dim,
            "yes",
            msg.payload_bits,
            msg.payload_bits as f32 / dim as f32,
            kashinflow::coordinator::protocol::upload_wire_bytes(&msg),
            c.is_unbiased()
        );
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args.remove(0);
    let quick = if let Some(pos) = args.iter().position(|a| a == "--quick") {
        args.remove(pos);
        true
    } else {
        false
    };
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            print_usage(&mut std::io::stdout());
            return;
        }
        "table1" => exp::table1::run(quick),
        "fig1a" => {
            exp::fig1::fig1a(quick);
        }
        "fig1b" => {
            exp::fig1::fig1b(quick);
        }
        "fig1c" => {
            exp::fig1::fig1c(quick);
        }
        "fig1d" => {
            exp::fig1::fig1d(quick);
        }
        "fig2ab" => {
            exp::fig2::fig2ab(quick);
        }
        "fig2cd" => {
            exp::fig2::fig2cd(quick);
        }
        "fig3a" => {
            exp::fig3::fig3a(quick);
        }
        "fig3b" => {
            if let Err(e) = exp::transformer::fig3b(quick) {
                eprintln!("fig3b failed: {e:#}");
                std::process::exit(1);
            }
        }
        "fig5" => {
            exp::fig3::fig5(quick);
        }
        "fig6" => {
            exp::fig3::fig6(quick);
        }
        "fig8" | "fig9" => {
            exp::appendix::fig8_9(quick);
        }
        "ablation-ef" => {
            exp::ablation::ablation_ef(quick);
        }
        "ablation-lambda" => {
            exp::ablation::ablation_lambda(quick);
        }
        "ablation-dqgd" => {
            exp::ablation::ablation_dqgd(quick);
        }
        "fig11" | "fig12" => {
            exp::appendix::fig11_12(quick);
        }
        "schemes" => {
            run_schemes(&args);
        }
        "net" => {
            exp::net::run(quick, &args);
        }
        "mesh" => {
            exp::mesh::run(quick, &args);
        }
        "serve" => {
            exp::serve::run(quick, &args);
        }
        "figures" => {
            exp::table1::run(quick);
            exp::fig1::fig1a(quick);
            exp::fig1::fig1b(quick);
            exp::fig1::fig1c(quick);
            exp::fig1::fig1d(quick);
            exp::fig2::fig2ab(quick);
            exp::fig2::fig2cd(quick);
            exp::fig3::fig3a(quick);
            exp::fig3::fig5(quick);
            exp::fig3::fig6(quick);
            exp::appendix::fig8_9(quick);
            exp::appendix::fig11_12(quick);
            exp::ablation::ablation_ef(quick);
            exp::ablation::ablation_lambda(quick);
            exp::ablation::ablation_dqgd(quick);
            // fig3b last: requires artifacts
            match exp::transformer::fig3b(quick) {
                Ok(_) => {}
                Err(e) => eprintln!("fig3b skipped: {e:#} (run `make artifacts`)"),
            }
        }
        "train" => {
            let cfg = match RunConfig::parse_args(&args) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("config error: {e}");
                    std::process::exit(2);
                }
            };
            run_train(&cfg);
        }
        "train-transformer" => {
            let cfg = match RunConfig::parse_args(&args) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("config error: {e}");
                    std::process::exit(2);
                }
            };
            match exp::transformer::train_federated(
                cfg.compressor_spec(),
                cfg.r,
                cfg.workers,
                cfg.rounds,
                cfg.step,
                cfg.seed,
            ) {
                Ok(metrics) => {
                    print!("{}", metrics.to_csv());
                    eprintln!(
                        "final loss {:.4}; {:.3} bits/dim/worker/round; {} total payload MB",
                        metrics.final_value(),
                        metrics.mean_rate(metrics.final_iterate.len(), cfg.workers),
                        metrics.total_payload_bits / 8 / 1_000_000
                    );
                }
                Err(e) => {
                    eprintln!("train-transformer failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            eprintln!("repro: unknown command '{cmd}'");
            usage();
        }
    }
}

/// Distributed training on a planted regression problem (the `train`
/// subcommand): the quickest way to exercise the full coordinator, via
/// the engine's distributed driver plumbing
/// ([`kashinflow::opt::engine::driver::run_config`]).
fn run_train(cfg: &RunConfig) {
    let mut rng = Rng::seed_from(cfg.seed);
    let s_local = 10;
    let (shards, xs) =
        planted_regression_shards(cfg.workers, s_local, cfg.n, Loss::Square, &mut rng, false);
    let global = ShardedProblem::new(shards.clone());
    let metrics =
        run_config(cfg, vec![0.0; cfg.n], shards, 7, &mut rng, |x| global.value(x));
    print!("{}", metrics.to_csv());
    let dist: f32 = kashinflow::linalg::vecops::dist2(&metrics.final_iterate, &xs);
    eprintln!(
        "scheme={} R={} workers={} transport={} part={}: final value {:.6}, ||x-x*||={:.4}, \
         rate {:.3} b/dim, mean participants {:.2}, rejected {}",
        cfg.scheme_name(),
        cfg.r,
        cfg.workers,
        cfg.transport.name(),
        cfg.participation,
        metrics.final_value(),
        dist,
        metrics.mean_rate(cfg.n, cfg.workers),
        metrics.mean_participants(),
        metrics.rejected_messages
    );
}
