//! MNIST-like synthetic digits — the offline substitute for the real
//! dataset used in Figs. 1d, 2c, 2d (see DESIGN.md §3).
//!
//! If a real MNIST IDX file pair is present (`MNIST_DIR` env var or
//! `data/mnist/`), it is loaded; otherwise a deterministic generator
//! produces 28×28 grayscale "digits": class-specific stroke templates
//! (vertical bar for "1", ring for "0") plus elastic jitter and pixel
//! noise. The substitution preserves what the experiments need — 784-dim
//! sparse non-negative features, two visually distinct, linearly separable
//! classes, heavy-tailed gradient spectra.

use crate::linalg::rng::Rng;
use crate::opt::objectives::{DatasetObjective, Loss};

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

/// A binary (0-vs-1) MNIST-like dataset with ±1 labels.
pub struct BinaryDigits {
    /// Row-major `m × 784`, pixel range [0, 1].
    pub x: Vec<f32>,
    /// Labels in {−1 (digit 0), +1 (digit 1)}.
    pub y: Vec<f32>,
    pub m: usize,
}

/// Render a "0": a ring centered in the image.
fn render_zero(img: &mut [f32], rng: &mut Rng) {
    let cx = 13.5 + rng.gaussian_f32() * 1.2;
    let cy = 13.5 + rng.gaussian_f32() * 1.2;
    let r_out = 8.0 + rng.gaussian_f32() * 0.9;
    let r_in = r_out - 2.5 - rng.uniform_f32();
    for i in 0..SIDE {
        for j in 0..SIDE {
            let d = (((i as f32 - cy).powi(2) + (j as f32 - cx).powi(2)) as f32).sqrt();
            if d <= r_out && d >= r_in.max(1.0) {
                img[i * SIDE + j] = (0.75 + 0.25 * rng.uniform_f32()).min(1.0);
            }
        }
    }
}

/// Render a "1": a near-vertical stroke.
fn render_one(img: &mut [f32], rng: &mut Rng) {
    let x0 = 13.5 + rng.gaussian_f32() * 1.5;
    let slant = rng.gaussian_f32() * 0.15;
    for i in 4..24 {
        let x = x0 + slant * (i as f32 - 14.0);
        let j0 = x.round() as i64;
        for dj in -1..=1i64 {
            let j = j0 + dj;
            if (0..SIDE as i64).contains(&j) {
                let v = if dj == 0 { 0.9 } else { 0.5 };
                img[i * SIDE + j as usize] = (v + 0.1 * rng.uniform_f32()).min(1.0);
            }
        }
    }
}

/// Generate `m` samples, alternating classes, with `noise` pixel noise.
pub fn generate_binary(m: usize, noise: f32, rng: &mut Rng) -> BinaryDigits {
    let mut x = vec![0.0f32; m * DIM];
    let mut y = vec![0.0f32; m];
    for s in 0..m {
        let img = &mut x[s * DIM..(s + 1) * DIM];
        let is_one = s % 2 == 1;
        if is_one {
            render_one(img, rng);
            y[s] = 1.0;
        } else {
            render_zero(img, rng);
            y[s] = -1.0;
        }
        if noise > 0.0 {
            for v in img.iter_mut() {
                if rng.bernoulli(0.02) {
                    *v = (*v + noise * rng.uniform_f32()).clamp(0.0, 1.0);
                }
            }
        }
    }
    BinaryDigits { x, y, m }
}

impl BinaryDigits {
    /// Hinge-loss SVM objective over this dataset (Fig. 2c/2d).
    pub fn svm_objective(&self) -> DatasetObjective {
        DatasetObjective::new(self.x.clone(), self.y.clone(), self.m, DIM, Loss::Hinge, 0.0)
    }

    /// Ridge-regression objective `½‖y − Xw‖² + reg/2·‖w‖²` (Fig. 1d).
    pub fn ridge_objective(&self, reg: f32) -> DatasetObjective {
        DatasetObjective::new(self.x.clone(), self.y.clone(), self.m, DIM, Loss::Square, reg)
    }

    /// Split into train/test.
    pub fn split(&self, train: usize) -> (BinaryDigits, BinaryDigits) {
        assert!(train < self.m);
        let tr = BinaryDigits {
            x: self.x[..train * DIM].to_vec(),
            y: self.y[..train].to_vec(),
            m: train,
        };
        let te = BinaryDigits {
            x: self.x[train * DIM..].to_vec(),
            y: self.y[train..].to_vec(),
            m: self.m - train,
        };
        (tr, te)
    }
}

/// Try to load real MNIST (IDX format) from `dir`; returns `None` when the
/// files are absent (the usual case on this offline image).
pub fn load_real_mnist_binary(dir: &str, m_cap: usize) -> Option<BinaryDigits> {
    let imgs = std::fs::read(format!("{dir}/train-images-idx3-ubyte")).ok()?;
    let lbls = std::fs::read(format!("{dir}/train-labels-idx1-ubyte")).ok()?;
    if imgs.len() < 16 || lbls.len() < 8 {
        return None;
    }
    let count = u32::from_be_bytes(imgs[4..8].try_into().ok()?) as usize;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..count {
        let lbl = lbls[8 + i];
        if lbl > 1 {
            continue; // keep only digits 0 and 1
        }
        let off = 16 + i * DIM;
        if off + DIM > imgs.len() {
            break;
        }
        x.extend(imgs[off..off + DIM].iter().map(|&p| p as f32 / 255.0));
        y.push(if lbl == 1 { 1.0 } else { -1.0 });
        if y.len() >= m_cap {
            break;
        }
    }
    if y.is_empty() {
        return None;
    }
    let m = y.len();
    Some(BinaryDigits { x, y, m })
}

/// Real MNIST if available, synthetic otherwise.
pub fn binary_digits(m: usize, rng: &mut Rng) -> BinaryDigits {
    let dir = std::env::var("MNIST_DIR").unwrap_or_else(|_| "data/mnist".into());
    load_real_mnist_binary(&dir, m).unwrap_or_else(|| generate_binary(m, 0.3, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::norm2;

    #[test]
    fn classes_are_linearly_separable() {
        let mut rng = Rng::seed_from(1);
        let data = generate_binary(200, 0.3, &mut rng);
        // Template difference is a separating direction: ones have center
        // column mass, zeros have ring mass.
        let obj = data.svm_objective();
        // Train a quick perceptron to verify separability.
        let mut w = vec![0.0f32; DIM];
        for _ in 0..50 {
            for s in 0..data.m {
                let xi = &data.x[s * DIM..(s + 1) * DIM];
                let pred: f32 = xi.iter().zip(&w).map(|(&a, &b)| a * b).sum();
                if pred * data.y[s] <= 0.0 {
                    for (wj, &xj) in w.iter_mut().zip(xi) {
                        *wj += data.y[s] * xj;
                    }
                }
            }
        }
        assert!(obj.classification_error(&w) < 0.05);
    }

    #[test]
    fn pixels_sparse_and_in_range() {
        let mut rng = Rng::seed_from(2);
        let data = generate_binary(50, 0.3, &mut rng);
        for s in 0..data.m {
            let img = &data.x[s * DIM..(s + 1) * DIM];
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let nz = img.iter().filter(|&&v| v > 0.0).count();
            assert!(nz > 10 && nz < DIM / 2, "nz={nz}");
        }
        assert!(norm2(&data.x) > 0.0);
    }

    #[test]
    fn split_partitions() {
        let mut rng = Rng::seed_from(3);
        let data = generate_binary(100, 0.1, &mut rng);
        let (tr, te) = data.split(80);
        assert_eq!(tr.m, 80);
        assert_eq!(te.m, 20);
        assert_eq!(tr.x.len(), 80 * DIM);
    }
}
