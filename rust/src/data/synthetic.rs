//! Synthetic problem generators from the paper's experiments.
//!
//! * Planted regression `b = A·x*` with Gaussian³ or Student-t heavy-tailed
//!   entries (Figs. 1b, 3a, 5, 6);
//! * Two-Gaussian SVM classes (Figs. 2a, 2b);
//! * Worker-sharded versions for the parameter-server experiments.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::matvec;
use crate::opt::objectives::{DatasetObjective, Loss};

/// Heavy-tail flavour of the planted model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tail {
    /// `N(0,1)` entries.
    Gaussian,
    /// `N(0,1)³` entries (Fig. 1a/1b/5).
    GaussianCubed,
    /// Student-t, df = 1 (Fig. 3a/6).
    StudentT1,
}

impl Tail {
    pub fn sample(self, rng: &mut Rng) -> f32 {
        match self {
            Tail::Gaussian => rng.gaussian_f32(),
            Tail::GaussianCubed => rng.gaussian_cubed(),
            Tail::StudentT1 => rng.student_t(1),
        }
    }
}

/// Planted least squares: `A (m×n)` with `a_tail` entries, `x* ~ x_tail`,
/// `b = A·x*`. Returns `(objective, x*)`.
pub fn planted_regression(
    m: usize,
    n: usize,
    a_tail: Tail,
    x_tail: Tail,
    reg: f32,
    rng: &mut Rng,
) -> (DatasetObjective, Vec<f32>) {
    let a: Vec<f32> = (0..m * n).map(|_| a_tail.sample(rng)).collect();
    let x_star: Vec<f32> = (0..n).map(|_| x_tail.sample(rng)).collect();
    let mut b = vec![0.0f32; m];
    matvec(&a, m, n, &x_star, &mut b);
    (DatasetObjective::new(a, b, m, n, Loss::Square, reg), x_star)
}

/// Worker-sharded planted regression: `m_workers` shards of `s` local
/// points each, all consistent with one global `x*` (the Fig. 3a / App. I
/// setup: `x* ~ Student-t`, `A ~ N(0,1)` when `student_t`; else Gaussian³).
pub fn planted_regression_shards(
    m_workers: usize,
    s: usize,
    n: usize,
    loss: Loss,
    rng: &mut Rng,
    student_t: bool,
) -> (Vec<DatasetObjective>, Vec<f32>) {
    let x_tail = if student_t { Tail::StudentT1 } else { Tail::GaussianCubed };
    let a_tail = if student_t { Tail::Gaussian } else { Tail::GaussianCubed };
    let x_star: Vec<f32> = (0..n).map(|_| x_tail.sample(rng)).collect();
    let shards = (0..m_workers)
        .map(|_| {
            let a: Vec<f32> = (0..s * n).map(|_| a_tail.sample(rng)).collect();
            let mut b = vec![0.0f32; s];
            matvec(&a, s, n, &x_star, &mut b);
            DatasetObjective::new(a, b, s, n, loss, 0.0)
        })
        .collect();
    (shards, x_star)
}

/// Two-Gaussian SVM data (Fig. 2a/2b): class `±1` drawn from
/// `N(±sep·1, I_n)`. Returns a hinge-loss objective.
pub fn two_gaussian_svm(m: usize, n: usize, sep: f32, rng: &mut Rng) -> DatasetObjective {
    let mut a = vec![0.0f32; m * n];
    let mut b = vec![0.0f32; m];
    for i in 0..m {
        let cls = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        for j in 0..n {
            a[i * n + j] = rng.gaussian_f32() + cls * sep;
        }
        b[i] = cls;
    }
    DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0)
}

/// Non-i.i.d. label-sharded split: each worker receives samples from at
/// most `classes_per_worker` classes (the Fig. 3b / Fig. 7 federated
/// setup). `labels[i] ∈ 0..n_classes`.
pub fn non_iid_shards(
    n_samples: usize,
    labels: &[usize],
    n_classes: usize,
    m_workers: usize,
    classes_per_worker: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert_eq!(labels.len(), n_samples);
    // Assign each worker a set of classes (round-robin over a shuffled
    // class list so every class is covered).
    let mut class_order: Vec<usize> = (0..n_classes).collect();
    for i in (1..n_classes).rev() {
        let j = rng.below(i + 1);
        class_order.swap(i, j);
    }
    let mut worker_classes: Vec<Vec<usize>> = vec![Vec::new(); m_workers];
    let mut k = 0;
    while worker_classes.iter().any(|w| w.len() < classes_per_worker) {
        for wc in worker_classes.iter_mut() {
            if wc.len() < classes_per_worker {
                wc.push(class_order[k % n_classes]);
                k += 1;
            }
        }
    }
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); m_workers];
    for (i, &lbl) in labels.iter().enumerate() {
        // among workers holding this class, pick one at random
        let holders: Vec<usize> =
            (0..m_workers).filter(|&w| worker_classes[w].contains(&lbl)).collect();
        if holders.is_empty() {
            shards[rng.below(m_workers)].push(i);
        } else {
            shards[holders[rng.below(holders.len())]].push(i);
        }
    }
    shards
}

/// Sanity metric used in tests: fraction of label mass in the modal class
/// of a shard (≈ 1/classes_per_worker for non-iid, ≈ 1/n_classes for iid).
pub fn shard_concentration(shard: &[usize], labels: &[usize], n_classes: usize) -> f32 {
    if shard.is_empty() {
        return 0.0;
    }
    let mut counts = vec![0usize; n_classes];
    for &i in shard {
        counts[labels[i]] += 1;
    }
    *counts.iter().max().unwrap() as f32 / shard.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dot;

    #[test]
    fn planted_regression_has_zero_loss_at_x_star() {
        let mut rng = Rng::seed_from(1);
        let (obj, xs) = planted_regression(50, 10, Tail::GaussianCubed, Tail::Gaussian, 0.0, &mut rng);
        assert!(obj.value(&xs) < 1e-4);
    }

    #[test]
    fn shards_share_the_planted_model() {
        let mut rng = Rng::seed_from(2);
        let (shards, xs) = planted_regression_shards(5, 8, 12, Loss::Square, &mut rng, true);
        for s in &shards {
            assert!(s.value(&xs) < 1e-4);
        }
    }

    #[test]
    fn two_gaussian_svm_is_roughly_separable() {
        let mut rng = Rng::seed_from(3);
        let obj = two_gaussian_svm(200, 30, 0.8, &mut rng);
        // The oracle direction (all-ones) separates most points.
        let w = vec![1.0f32; 30];
        assert!(obj.classification_error(&w) < 0.1);
    }

    #[test]
    fn non_iid_shards_are_concentrated() {
        let mut rng = Rng::seed_from(4);
        let n = 2000;
        let n_classes = 10;
        let labels: Vec<usize> = (0..n).map(|_| rng.below(n_classes)).collect();
        let shards = non_iid_shards(n, &labels, n_classes, 10, 2, &mut rng);
        // all samples assigned exactly once
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, n);
        // each shard is dominated by <= 2 classes
        for s in &shards {
            if s.len() < 20 {
                continue;
            }
            let mut counts = vec![0usize; n_classes];
            for &i in s {
                counts[labels[i]] += 1;
            }
            let nonzero = counts.iter().filter(|&&c| c > 0).count();
            assert!(nonzero <= 2, "shard has {nonzero} classes");
        }
    }

    #[test]
    fn student_t_tail_heavier_than_gaussian() {
        let mut rng = Rng::seed_from(5);
        let big_t = (0..20_000).filter(|_| Tail::StudentT1.sample(&mut rng).abs() > 5.0).count();
        let big_g = (0..20_000).filter(|_| Tail::Gaussian.sample(&mut rng).abs() > 5.0).count();
        assert!(big_t > big_g * 10, "t:{big_t} g:{big_g}");
    }

    #[test]
    fn gaussian_generator_rows_have_expected_norm() {
        let mut rng = Rng::seed_from(6);
        let (obj, _) = planted_regression(30, 50, Tail::Gaussian, Tail::Gaussian, 0.0, &mut rng);
        let mean_sq: f32 = (0..30)
            .map(|i| dot(&obj.a[i * 50..(i + 1) * 50], &obj.a[i * 50..(i + 1) * 50]) / 50.0)
            .sum::<f32>()
            / 30.0;
        assert!((mean_sq - 1.0).abs() < 0.15, "row E[a²]={mean_sq}");
    }
}
