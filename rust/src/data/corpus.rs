//! Byte-level tiny corpus for the end-to-end transformer example
//! (Fig. 3b's non-convex workload, adapted per DESIGN.md §3).
//!
//! A deterministic generator emits structured pseudo-English — Markovian
//! word soup over a small vocabulary with punctuation — giving the language
//! model real statistical structure (so the loss curve *moves*) without any
//! external data. Batching produces `(tokens, next-token targets)` pairs.

use crate::linalg::rng::Rng;

pub const VOCAB: usize = 64;

const WORDS: &[&str] = &[
    "the", "a", "of", "and", "to", "in", "is", "that", "it", "was", "for", "on", "are", "as",
    "with", "his", "they", "be", "at", "one", "have", "this", "from", "or", "had", "by", "hot",
    "word", "but", "what", "some", "we", "can", "out", "other", "were", "all", "there", "when",
    "up", "use", "your", "how", "said", "an", "each", "she",
];

/// Map a byte to the token alphabet: lowercase letters, space and a few
/// punctuation marks; everything else folds onto space.
fn tokenize_byte(b: u8) -> u8 {
    match b {
        b'a'..=b'z' => b - b'a' + 1,       // 1..26
        b'A'..=b'Z' => b - b'A' + 1,       // fold case
        b'.' => 27,
        b',' => 28,
        b'\n' => 29,
        b'0'..=b'9' => 30 + (b - b'0') % 8, // 30..37
        _ => 0,                             // space
    }
}

/// Generate `len` tokens of pseudo-English.
pub fn generate_tokens(len: usize, rng: &mut Rng) -> Vec<u8> {
    let mut text = String::with_capacity(len * 2);
    while text.len() < len + 16 {
        let sentence_len = 4 + rng.below(10);
        for w in 0..sentence_len {
            if w > 0 {
                text.push(' ');
            }
            text.push_str(WORDS[rng.below(WORDS.len())]);
        }
        text.push_str(if rng.bernoulli(0.8) { ". " } else { ",\n" });
    }
    text.bytes().take(len).map(tokenize_byte).collect()
}

/// A corpus with sequential batching for next-token prediction.
pub struct Corpus {
    pub tokens: Vec<u8>,
}

impl Corpus {
    pub fn synthetic(len: usize, rng: &mut Rng) -> Self {
        Corpus { tokens: generate_tokens(len, rng) }
    }

    /// Sample a batch of `(inputs, targets)` windows of length `seq`.
    /// Returned as flat `batch×seq` u32 arrays (the dtype the HLO expects).
    pub fn batch(&self, batch: usize, seq: usize, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
        assert!(self.tokens.len() > seq + 1);
        let mut xs = Vec::with_capacity(batch * seq);
        let mut ys = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - seq - 1);
            for t in 0..seq {
                xs.push(self.tokens[start + t] as u32);
                ys.push(self.tokens[start + t + 1] as u32);
            }
        }
        (xs, ys)
    }

    /// Shard the corpus non-iid: worker `i` sees a contiguous region (so
    /// token statistics differ across workers, mimicking the paper's
    /// label-sharded CIFAR split).
    pub fn shard(&self, m_workers: usize) -> Vec<Corpus> {
        let chunk = self.tokens.len() / m_workers;
        (0..m_workers)
            .map(|i| Corpus { tokens: self.tokens[i * chunk..(i + 1) * chunk].to_vec() })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut rng = Rng::seed_from(1);
        let toks = generate_tokens(5000, &mut rng);
        assert_eq!(toks.len(), 5000);
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB));
    }

    #[test]
    fn tokens_have_structure() {
        // Letter bigram entropy of structured text is far below uniform.
        let mut rng = Rng::seed_from(2);
        let toks = generate_tokens(20_000, &mut rng);
        let mut counts = vec![0u32; VOCAB];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        let total: u32 = counts.iter().sum();
        let entropy: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        assert!(entropy < 4.6, "entropy {entropy} too close to uniform(6 bits)");
        assert!(entropy > 2.0);
    }

    #[test]
    fn batches_are_shifted_pairs() {
        let mut rng = Rng::seed_from(3);
        let c = Corpus::synthetic(2000, &mut rng);
        let (xs, ys) = c.batch(4, 16, &mut rng);
        assert_eq!(xs.len(), 64);
        assert_eq!(ys.len(), 64);
        // within each window, ys[t] == xs[t+1]
        for bidx in 0..4 {
            for t in 0..15 {
                assert_eq!(ys[bidx * 16 + t], xs[bidx * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn shards_partition_and_differ() {
        let mut rng = Rng::seed_from(4);
        let c = Corpus::synthetic(9000, &mut rng);
        let shards = c.shard(3);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.tokens.len() == 3000));
    }
}
