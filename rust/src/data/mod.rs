//! Data substrate: synthetic generators matching the paper's simulation
//! setups, an MNIST-like digit generator (substitute for the real dataset,
//! which is not available offline — see DESIGN.md §3), and a byte-level
//! corpus for the end-to-end transformer example.

pub mod corpus;
pub mod mnist_like;
pub mod synthetic;
