//! A compiled HLO artifact: load text → compile once → execute many.
//!
//! Artifacts are HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see `/opt/xla-example/README.md`). Lowering uses
//! `return_tuple=True`, so executables return a 1-tuple that we flatten.

use anyhow::{Context, Result};

use crate::runtime::client::with_cpu_client;

/// Typed input buffer for an artifact call.
pub enum Input<'a> {
    F32(&'a [f32], Vec<usize>),
    U32(&'a [u32], Vec<usize>),
}

/// A loaded, compiled HLO computation.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Artifact {
    /// Load and compile `path` (HLO text file).
    pub fn load(path: &str) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            with_cpu_client(|c| c.compile(&comp)).with_context(|| format!("compiling {path}"))?;
        Ok(Artifact { exe, name: path.to_string() })
    }

    /// Execute with the given inputs; returns all outputs flattened to f32
    /// vectors (model artifacts emit f32 tensors).
    pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                Ok(match inp {
                    Input::F32(data, shape) => {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(data).reshape(&dims)?
                    }
                    Input::U32(data, shape) => {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(data).reshape(&dims)?
                    }
                })
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // return_tuple=True => outputs arrive as a tuple; decompose.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute and return the single output (convenience).
    pub fn run1_f32(&self, inputs: &[Input]) -> Result<Vec<f32>> {
        let mut outs = self.run_f32(inputs)?;
        anyhow::ensure!(outs.len() == 1, "{}: expected 1 output, got {}", self.name, outs.len());
        Ok(outs.pop().unwrap())
    }
}

/// Default artifacts directory (overridable for tests).
pub fn artifacts_dir() -> String {
    std::env::var("KASHINFLOW_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Minimal HLO text computation: f(x) = x + x over f32[4] (1-tuple).
    const ADD_HLO: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main.5 {
  Arg_0.1 = f32[4]{0} parameter(0)
  add.3 = f32[4]{0} add(Arg_0.1, Arg_0.1)
  ROOT tuple.4 = (f32[4]{0}) tuple(add.3)
}
"#;

    // Requires the real xla/PJRT bindings; the offline stub in
    // rust/vendor/xla returns errors from every entry point. Run with
    // `cargo test -- --ignored` after swapping the real bindings in.
    #[test]
    #[ignore = "needs real xla/PJRT bindings (offline stub build)"]
    fn loads_and_runs_handwritten_hlo() {
        let dir = std::env::temp_dir().join("kashinflow_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("add.hlo.txt");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(ADD_HLO.as_bytes()).unwrap();
        drop(f);
        let art = Artifact::load(path.to_str().unwrap()).unwrap();
        let out = art.run1_f32(&[Input::F32(&[1.0, 2.0, 3.0, 4.0], vec![4])]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
    }
}
