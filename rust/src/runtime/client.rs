//! Thread-local PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so
//! each thread that touches the runtime gets its own client, created
//! lazily. In the coordinator topology this is exactly one client per
//! model-executing worker thread — artifacts are loaded and run on the
//! thread that owns them.

use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's CPU client (created on first use).
pub fn with_cpu_client<R>(f: impl FnOnce(&xla::PjRtClient) -> R) -> R {
    CLIENT.with(|cell| {
        let client =
            cell.get_or_init(|| xla::PjRtClient::cpu().expect("failed to create PJRT CPU client"));
        f(client)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // Requires the real xla/PJRT bindings; the offline stub in
    // rust/vendor/xla fails client creation by design.
    #[test]
    #[ignore = "needs real xla/PJRT bindings (offline stub build)"]
    fn client_is_cpu_and_cached() {
        let name1 = with_cpu_client(|c| c.platform_name());
        let name2 = with_cpu_client(|c| c.platform_name());
        assert_eq!(name1, "cpu");
        assert_eq!(name2, "cpu");
        let devs = with_cpu_client(|c| c.device_count());
        assert!(devs >= 1);
    }
}
