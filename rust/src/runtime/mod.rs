//! PJRT runtime — loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and executes them from the Rust hot path.
//! Python is never on the request path: after `make artifacts` the binary
//! is self-contained.

pub mod artifact;
pub mod client;

pub use artifact::Artifact;
