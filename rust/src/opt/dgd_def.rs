//! **DGD-DEF** — Distributed Gradient Descent with Democratically Encoded
//! Feedback (Algorithm 1).
//!
//! The worker keeps the quantization error `e_{t−1}`, evaluates the
//! gradient at the *shifted* point `z_t = x̂_t + α·e_{t−1}` (which makes
//! `z_t` track the **unquantized** GD trajectory exactly — the recursive
//! invariant of App. D), subtracts the error from the gradient before
//! encoding, and sends the (N)DSC codeword. Theorem 2: the iterates
//! converge linearly at rate `max{ν, β}` with `β = 2^{1−R/λ}K_u` (DSC) or
//! `2^{2−R/λ}√log(2N)` (NDSC) — dimension-free, matching the
//! `max{σ, 2^{−R}}` lower bound up to constants.
//!
//! Engine spec: `ExactGrad` oracle, constant step, shared codec,
//! [`DefFeedback`] memory, last-iterate output with trailing record.

use crate::linalg::rng::Rng;
use crate::opt::engine::feedback::DefFeedback;
use crate::opt::engine::oracle::ExactGrad;
use crate::opt::engine::schedule::{optimal_sc_step, Schedule};
use crate::opt::engine::{Codecs, Engine, Problem};
use crate::opt::objectives::DatasetObjective;
use crate::opt::Trace;
use crate::quant::Compressor;

/// Options for a DGD-DEF run.
#[derive(Clone, Copy, Debug)]
pub struct DgdDefOptions {
    /// Step size `α ≤ α* = 2/(L+μ)`.
    pub step: f32,
    pub iters: usize,
}

impl DgdDefOptions {
    /// Thm. 2's optimal step — single-sourced in
    /// [`crate::opt::engine::schedule`].
    pub fn optimal(l: f32, mu: f32, iters: usize) -> Self {
        DgdDefOptions { step: optimal_sc_step(l, mu), iters }
    }
}

/// Run Algorithm 1 with the given compressor as `(E, D)`.
pub fn run(
    obj: &DatasetObjective,
    compressor: &dyn Compressor,
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: DgdDefOptions,
    rng: &mut Rng,
) -> Trace {
    Engine::new(Problem::Single(obj), Schedule::Constant(opts.step), opts.iters)
        .with_oracle(ExactGrad { obj })
        .with_codecs(Codecs::Shared(compressor))
        .with_feedback(DefFeedback::new(1, obj.dim()))
        .run(x0, x_star, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frames::HadamardFrame;
    use crate::linalg::vecops::{dist2, matvec};
    use crate::opt::gd::sigma;
    use crate::opt::objectives::Loss;
    use crate::quant::gain_shape::NaiveUniform;
    use crate::quant::ndsc::Ndsc;

    fn planted_lsq(m: usize, n: usize, seed: u64) -> (DatasetObjective, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        // Gaussian-cubed entries as in Fig. 1b.
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_cubed()).collect();
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; m];
        matvec(&a, m, n, &xs, &mut b);
        (DatasetObjective::new(a, b, m, n, Loss::Square, 0.1), xs)
    }

    #[test]
    fn converges_with_ndsc_at_moderate_budget() {
        let (obj, _) = planted_lsq(80, 30, 1);
        let xs = obj.quadratic_minimizer();
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(2);
        let c = Ndsc::hadamard(30, 6.0, &mut rng);
        let trace =
            run(&obj, &c, &vec![0.0; 30], Some(&xs), DgdDefOptions::optimal(l, mu, 200), &mut rng);
        let d_end = trace.records.last().unwrap().dist_to_opt;
        let d_0 = trace.records[0].dist_to_opt;
        assert!(d_end < 1e-2 * d_0, "no convergence: {d_end} vs {d_0}");
    }

    #[test]
    fn rate_approaches_sigma_at_high_budget() {
        // Thm 2: for R large, max{ν, β} = ν → σ.
        let (obj, _) = planted_lsq(60, 16, 3);
        let xs = obj.quadratic_minimizer();
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(4);
        let c = Ndsc::hadamard(16, 10.0, &mut rng);
        let trace =
            run(&obj, &c, &vec![0.0; 16], Some(&xs), DgdDefOptions::optimal(l, mu, 150), &mut rng);
        let s = sigma(l, mu);
        assert!(
            trace.empirical_rate() <= s + 0.05,
            "rate {} should be near sigma {s}",
            trace.empirical_rate()
        );
    }

    #[test]
    fn ndsc_converges_where_naive_fails() {
        // The Fig. 1b crossover: at a low budget NDSC converges while the
        // naive scalar quantizer (sqrt(n) penalty) does not.
        let (obj, _) = planted_lsq(200, 116, 5);
        let xs = obj.quadratic_minimizer();
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(6);
        let r = 3.0;
        let opts = DgdDefOptions::optimal(l, mu, 120);
        let ndsc = Ndsc::hadamard(116, r, &mut rng);
        let t_ndsc = run(&obj, &ndsc, &vec![0.0; 116], Some(&xs), opts, &mut rng);
        let naive = NaiveUniform::new(116, r);
        let t_naive = run(&obj, &naive, &vec![0.0; 116], Some(&xs), opts, &mut rng);
        assert!(
            t_ndsc.empirical_rate() < t_naive.empirical_rate(),
            "NDSC {} !< naive {}",
            t_ndsc.empirical_rate(),
            t_naive.empirical_rate()
        );
        assert!(t_ndsc.empirical_rate() < 1.0);
    }

    #[test]
    fn error_feedback_invariant_tracks_unquantized_gd() {
        // App. D: x̂_t = x_t − α·e_{t−1}, i.e. z_t equals the unquantized GD
        // trajectory. We verify by running both and reconstructing z.
        let (obj, _) = planted_lsq(40, 8, 7);
        let (l, mu) = obj.smoothness_strong_convexity();
        let step = 2.0 / (l + mu);
        let mut rng = Rng::seed_from(8);
        let frame = HadamardFrame::new(8, &mut rng);
        let c = Ndsc::new(frame, 4.0);
        // Manual DGD-DEF, checking the invariant each step.
        let n = 8;
        let mut xhat = vec![0.0f32; n];
        let mut e = vec![0.0f32; n];
        let mut x_gd = vec![0.0f32; n]; // unquantized trajectory
        let mut g = vec![0.0f32; n];
        for _ in 0..30 {
            // invariant: x_gd == xhat + step*e
            let z: Vec<f32> =
                xhat.iter().zip(&e).map(|(&xi, &ei)| xi + step * ei).collect();
            assert!(dist2(&z, &x_gd) < 1e-2 * (1.0 + crate::linalg::vecops::norm2(&x_gd)));
            // advance unquantized GD
            obj.gradient(&x_gd, &mut g);
            for (xi, &gi) in x_gd.iter_mut().zip(&g) {
                *xi -= step * gi;
            }
            // advance DGD-DEF
            obj.gradient(&z, &mut g);
            let u: Vec<f32> = g.iter().zip(&e).map(|(&gi, &ei)| gi - ei).collect();
            let q = c.decompress(&c.compress(&u, &mut rng));
            for ((ei, &qi), &ui) in e.iter_mut().zip(&q).zip(&u) {
                *ei = qi - ui;
            }
            for (xi, &qi) in xhat.iter_mut().zip(&q) {
                *xi -= step * qi;
            }
        }
    }

    #[test]
    fn bits_accounted_per_iteration() {
        let (obj, _) = planted_lsq(30, 10, 9);
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(10);
        let c = Ndsc::hadamard(10, 2.0, &mut rng);
        let iters = 25;
        let trace = run(
            &obj,
            &c,
            &vec![0.0; 10],
            None,
            DgdDefOptions { step: 2.0 / (l + mu), iters },
            &mut rng,
        );
        assert_eq!(trace.total_payload_bits, iters * crate::quant::budget_bits(10, 2.0));
    }
}
