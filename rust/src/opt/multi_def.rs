//! Multi-worker DGD-DEF — the extension sketched in §4.3 / [6, Sec. 5]:
//! each worker runs its **own** error-feedback loop on its local gradient
//! and the server averages the decoded corrections.
//!
//! The paper leaves the full multi-worker error-feedback characterization
//! open ("a complete characterization … is still an open problem"); this
//! module implements the per-worker-feedback variant it points to, which
//! is exact for smooth strongly-convex sums and recovers single-worker
//! DGD-DEF at m = 1 (tested).
//!
//! Engine spec: one `ExactGrad` per shard, per-worker codecs,
//! [`DefFeedback`] with one error vector per worker (a non-participant's
//! loop pauses), k-of-m participation, last-iterate output. Codec dither
//! draws from the shared run RNG in participant order — the historical
//! convention of this loop, preserved bit-for-bit.

use crate::coordinator::transport::Participation;
use crate::linalg::rng::Rng;
use crate::opt::engine::feedback::DefFeedback;
use crate::opt::engine::oracle::ExactGrad;
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::{Codecs, Engine, Problem};
use crate::opt::multi::ShardedProblem;
use crate::opt::Trace;
use crate::quant::Compressor;

#[derive(Clone, Copy, Debug)]
pub struct MultiDefOptions {
    pub step: f32,
    pub iters: usize,
    /// Partial participation: under `KofM` only a seeded random k-subset
    /// computes each round; a non-participant's error term `e_i` simply
    /// carries over unchanged (its feedback loop pauses). `Deadline`
    /// degrades to `Full` in this network-free reference loop.
    pub participation: Participation,
}

/// Run multi-worker DGD-DEF: worker `i` holds `e_i`, computes
/// `u_i = ∇f_i(x̂ + α·e_i) − e_i`, sends `E_i(u_i)`; the server steps on
/// the average of the decodes.
pub fn run(
    problem: &ShardedProblem,
    compressors: &[Box<dyn Compressor>],
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: MultiDefOptions,
    rng: &mut Rng,
) -> Trace {
    let mut spec = Engine::new(
        Problem::Sharded(problem),
        Schedule::Constant(opts.step),
        opts.iters,
    )
    .with_codecs(Codecs::PerWorker(compressors))
    .with_feedback(DefFeedback::new(problem.m(), problem.n))
    .with_participation(opts.participation);
    for shard in &problem.shards {
        spec = spec.with_oracle(ExactGrad { obj: shard });
    }
    spec.run(x0, x_star, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::planted_regression_shards;
    use crate::linalg::vecops::dist2;
    use crate::opt::objectives::Loss;
    use crate::quant::ndsc::Ndsc;

    fn setup(m: usize, seed: u64) -> (ShardedProblem, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let (shards, xs) = planted_regression_shards(m, 20, 16, Loss::Square, &mut rng, false);
        (ShardedProblem::new(shards), xs)
    }

    #[test]
    fn converges_linearly_on_quadratic_sum() {
        let (problem, xs) = setup(5, 1);
        let mut rng = Rng::seed_from(2);
        let comps: Vec<Box<dyn Compressor>> =
            (0..5).map(|_| Box::new(Ndsc::hadamard(16, 4.0, &mut rng)) as _).collect();
        let opts = MultiDefOptions {
            step: problem.stable_step(),
            iters: 200,
            participation: Participation::Full,
        };
        let tr = run(&problem, &comps, &vec![0.0; 16], Some(&xs), opts, &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 1e-2 * d0, "no linear convergence: {d0} -> {dt}");
    }

    #[test]
    fn partial_participation_pauses_feedback_but_converges() {
        // 3-of-5 per round: each worker's error loop advances only when
        // it participates; the quadratic sum must still contract.
        let (problem, xs) = setup(5, 7);
        let mut rng = Rng::seed_from(8);
        let comps: Vec<Box<dyn Compressor>> =
            (0..5).map(|_| Box::new(Ndsc::hadamard(16, 4.0, &mut rng)) as _).collect();
        let opts = MultiDefOptions {
            step: problem.stable_step(),
            iters: 400,
            participation: Participation::KofM { k: 3 },
        };
        let tr = run(&problem, &comps, &vec![0.0; 16], Some(&xs), opts, &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 0.1 * d0, "no convergence under 3-of-5: {d0} -> {dt}");
    }

    #[test]
    fn reduces_to_single_worker_dgd_def() {
        // m = 1 must match opt::dgd_def exactly (same codec, same seed).
        let mut rng = Rng::seed_from(3);
        let (shards, xs) =
            planted_regression_shards(1, 30, 12, Loss::Square, &mut rng, false);
        let obj = shards[0].clone();
        let problem = ShardedProblem::new(shards);
        let step = problem.stable_step();
        let mut rng_a = Rng::seed_from(10);
        let c_a = Ndsc::hadamard(12, 3.0, &mut rng_a);
        let tr_a = run(
            &problem,
            &[Box::new(c_a)],
            &vec![0.0; 12],
            Some(&xs),
            MultiDefOptions { step, iters: 40, participation: Participation::Full },
            &mut Rng::seed_from(11),
        );
        let mut rng_b = Rng::seed_from(10);
        let c_b = Ndsc::hadamard(12, 3.0, &mut rng_b);
        let tr_b = crate::opt::dgd_def::run(
            &obj,
            &c_b,
            &vec![0.0; 12],
            Some(&xs),
            crate::opt::dgd_def::DgdDefOptions { step, iters: 40 },
            &mut Rng::seed_from(11),
        );
        assert!(
            dist2(&tr_a.final_x, &tr_b.final_x) < 1e-4,
            "m=1 multi-DEF must equal DGD-DEF"
        );
    }

    #[test]
    fn feedback_beats_no_feedback_at_low_budget() {
        // The ablation DESIGN.md calls out: per-worker error feedback vs
        // plain quantized consensus GD, same deterministic codec, R = 2.
        let (problem, xs) = setup(4, 4);
        let step = problem.stable_step();
        let mut rng = Rng::seed_from(5);
        let with: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(Ndsc::hadamard(16, 2.0, &mut rng)) as _).collect();
        let tr_ef = run(
            &problem,
            &with,
            &vec![0.0; 16],
            Some(&xs),
            MultiDefOptions { step, iters: 150, participation: Participation::Full },
            &mut rng,
        );
        // No feedback: same codec through the plain consensus loop.
        let without: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(Ndsc::hadamard(16, 2.0, &mut rng)) as _).collect();
        let tr_plain = crate::opt::multi::run(
            &problem,
            &without,
            &vec![0.0; 16],
            Some(&xs),
            crate::opt::multi::MultiOptions {
                step,
                iters: 150,
                domain: crate::opt::projection::Domain::Unconstrained,
                batch: None,
                participation: Participation::Full,
            },
            &mut rng,
        );
        let d_ef = tr_ef.records.last().unwrap().dist_to_opt;
        let d_plain = tr_plain.records.last().unwrap().dist_to_opt;
        assert!(
            d_ef < d_plain,
            "error feedback should tighten the noise ball: EF {d_ef} vs plain {d_plain}"
        );
    }
}
