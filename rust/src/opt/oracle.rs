//! First-order oracles — the worker-side gradient access of §1.
//!
//! * [`ExactOracle`] — deterministic `∇f(x)` (setting (i), §4.1).
//! * [`MinibatchOracle`] — unbiased stochastic subgradient from a random
//!   minibatch (setting (ii), §4.2/§5, where "the stochasticity … arises
//!   from randomly subsampling the dataset").

use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm2;
use crate::opt::objectives::DatasetObjective;

/// A (possibly stochastic) subgradient oracle.
pub trait Oracle: Send {
    fn dim(&self) -> usize;
    /// Write a (sub)gradient estimate at `x` into `out`.
    fn query(&mut self, x: &[f32], out: &mut [f32]);
    /// Uniform bound `B` with `‖ĝ(x)‖₂ ≤ B` over the domain of interest
    /// (needed for DQ-PSGD's step size).
    fn bound(&self) -> f32;
}

/// Exact full-gradient oracle.
pub struct ExactOracle<'a> {
    pub obj: &'a DatasetObjective,
    bound: f32,
}

impl<'a> ExactOracle<'a> {
    pub fn new(obj: &'a DatasetObjective, bound: f32) -> Self {
        ExactOracle { obj, bound }
    }
}

impl Oracle for ExactOracle<'_> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn query(&mut self, x: &[f32], out: &mut [f32]) {
        self.obj.gradient(x, out);
    }

    fn bound(&self) -> f32 {
        self.bound
    }
}

/// Random-minibatch stochastic subgradient oracle (unbiased). Queries are
/// allocation-free: the batch index buffer is owned and reused.
pub struct MinibatchOracle<'a> {
    pub obj: &'a DatasetObjective,
    pub batch: usize,
    rng: Rng,
    bound: f32,
    idx: Vec<usize>,
}

impl<'a> MinibatchOracle<'a> {
    pub fn new(obj: &'a DatasetObjective, batch: usize, rng: Rng) -> Self {
        assert!(batch >= 1 && batch <= obj.m);
        // Conservative subgradient bound for the supported losses:
        // each per-sample subgradient has norm <= max_i ||a_i|| (hinge,
        // logistic; coefficient in [-1,1]); square loss is bounded on the
        // iterate ball — callers can override via with_bound.
        let mut max_row = 0.0f32;
        for i in 0..obj.m {
            max_row = max_row.max(norm2(&obj.a[i * obj.n..(i + 1) * obj.n]));
        }
        MinibatchOracle { obj, batch, rng, bound: max_row, idx: Vec::new() }
    }

    pub fn with_bound(mut self, b: f32) -> Self {
        self.bound = b;
        self
    }
}

impl Oracle for MinibatchOracle<'_> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn query(&mut self, x: &[f32], out: &mut [f32]) {
        self.rng.sample_indices_into(self.obj.m, self.batch, &mut self.idx);
        self.obj.minibatch_gradient(x, Some(&self.idx), out);
    }

    fn bound(&self) -> f32 {
        self.bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;
    use crate::opt::objectives::Loss;

    fn svm_objective(seed: u64) -> DatasetObjective {
        let mut rng = Rng::seed_from(seed);
        let (m, n) = (40, 6);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.sign()).collect();
        DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0)
    }

    #[test]
    fn exact_oracle_is_gradient() {
        let obj = svm_objective(1);
        let mut oracle = ExactOracle::new(&obj, 10.0);
        let x = vec![0.1f32; 6];
        let mut g1 = vec![0.0f32; 6];
        let mut g2 = vec![0.0f32; 6];
        oracle.query(&x, &mut g1);
        obj.gradient(&x, &mut g2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn minibatch_oracle_unbiased_and_bounded() {
        let obj = svm_objective(2);
        let mut oracle = MinibatchOracle::new(&obj, 8, Rng::seed_from(3));
        let x = vec![0.05f32; 6];
        let mut full = vec![0.0f32; 6];
        obj.gradient(&x, &mut full);
        let trials = 3000;
        let mut mean = vec![0.0f64; 6];
        let mut g = vec![0.0f32; 6];
        for _ in 0..trials {
            oracle.query(&x, &mut g);
            assert!(norm2(&g) <= oracle.bound() * 1.01, "||g||={} B={}", norm2(&g), oracle.bound());
            for (m, &v) in mean.iter_mut().zip(&g) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &full) < 0.05 * (1.0 + norm2(&full)));
    }
}
