//! Multi-worker DQ-PSGD (Algorithm 3) — the single-process algorithmic
//! reference for §4.3 / Appendix I.
//!
//! Each of the `m` workers holds a private shard `f_i`; per round the
//! server broadcasts `x̂_t`, every worker sends a dithered democratic
//! codeword of its local stochastic subgradient, and the server averages
//! the decoded estimates (consensus step) before the projected step.
//! App. I: the quantization variance enters as `σ_q²/m` with
//! `σ_q² = n·B²/(2^R−1)²` for the naive quantizer vs `K_u²/(2^R−1)²`
//! (DSC) / `log n/(2^R−1)²` (NDSC) — the `n`-free rates of (24)/(25).
//!
//! Engine spec: one [`ShardOracle`] per worker (batch draw from the
//! worker's forked RNG stream), per-worker codecs, no feedback, k-of-m
//! participation, Polyak-average output. The threaded, byte-accounted
//! runtime for the same spec is
//! [`crate::opt::engine::driver::CoordinatorDriver`]; this inline form is
//! deterministic and cheap, used by the figure harness (Figs. 3a, 5, 6).

use crate::coordinator::transport::Participation;
use crate::linalg::rng::Rng;
use crate::opt::engine::oracle::ShardOracle;
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::{Codecs, Engine, OutputMode, Problem, RngPolicy};
use crate::opt::objectives::DatasetObjective;
use crate::opt::projection::Domain;
use crate::opt::Trace;
use crate::quant::Compressor;

/// A multi-worker problem: one objective shard per worker; the global
/// objective is the average.
pub struct ShardedProblem {
    pub shards: Vec<DatasetObjective>,
    pub n: usize,
}

impl ShardedProblem {
    pub fn new(shards: Vec<DatasetObjective>) -> Self {
        assert!(!shards.is_empty());
        let n = shards[0].dim();
        assert!(shards.iter().all(|s| s.dim() == n));
        ShardedProblem { shards, n }
    }

    pub fn m(&self) -> usize {
        self.shards.len()
    }

    /// Global objective `f(x) = (1/m)Σ f_i(x)`.
    pub fn value(&self, x: &[f32]) -> f32 {
        self.shards.iter().map(|s| s.value(x)).sum::<f32>() / self.m() as f32
    }

    /// A step size stable for quadratic shards: `0.8 / max_i L_i` (heavy-
    /// tailed data can make `L` huge, so a fixed nominal step diverges).
    pub fn stable_step(&self) -> f32 {
        let l_max = self
            .shards
            .iter()
            .map(|s| s.smoothness_strong_convexity().0)
            .fold(0.0f32, f32::max);
        0.8 / l_max.max(1e-6)
    }

    /// Global full gradient.
    pub fn gradient(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let mut g = vec![0.0f32; self.n];
        for s in &self.shards {
            s.gradient(x, &mut g);
            for (o, &gi) in out.iter_mut().zip(&g) {
                *o += gi / self.m() as f32;
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MultiOptions {
    pub step: f32,
    pub iters: usize,
    pub domain: Domain,
    /// Worker minibatch size (`None` = full local gradient).
    pub batch: Option<usize>,
    /// Partial participation: `KofM` draws a uniformly random k-subset of
    /// workers per round (the randomized-participation model of the
    /// quantized coordinate-descent literature); only they compute,
    /// compress and join the consensus average. `Deadline` degrades to
    /// `Full` here — this single-process reference loop has no network,
    /// so every "arrival" is instant (the coordinator's SimNet transport
    /// is where deadlines bite).
    pub participation: Participation,
}

/// Run Algorithm 3: one compressor instance **per worker** (each worker
/// draws its own frame randomness), consensus averaging at the server
/// over the round's participant set (all workers under full
/// participation; a seeded random k-subset under `KofM`).
pub fn run(
    problem: &ShardedProblem,
    compressors: &[Box<dyn Compressor>],
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: MultiOptions,
    rng: &mut Rng,
) -> Trace {
    let mut spec = Engine::new(
        Problem::Sharded(problem),
        Schedule::Constant(opts.step),
        opts.iters,
    )
    .with_codecs(Codecs::PerWorker(compressors))
    .with_rng_policy(RngPolicy::ForkPerWorker)
    .with_participation(opts.participation)
    .with_domain(opts.domain)
    .with_output(OutputMode::PolyakAverage);
    for shard in &problem.shards {
        spec = spec.with_oracle(ShardOracle::new(shard, opts.batch));
    }
    spec.run(x0, x_star, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::planted_regression_shards;
    use crate::linalg::vecops::dist2;
    use crate::opt::objectives::Loss;
    use crate::quant::gain_shape::StandardDither;
    use crate::quant::ndsc::Ndsc;
    use crate::quant::Compressor;

    fn make_compressors(
        m: usize,
        n: usize,
        r: f32,
        ndsc: bool,
        rng: &mut Rng,
    ) -> Vec<Box<dyn Compressor>> {
        (0..m)
            .map(|_| -> Box<dyn Compressor> {
                if ndsc {
                    Box::new(Ndsc::hadamard_dithered(n, r, rng))
                } else {
                    Box::new(StandardDither::new(n, r))
                }
            })
            .collect()
    }

    #[test]
    fn multiworker_regression_converges_with_ndsc() {
        // Fig. 3a setup: n=30, m=10 workers, s=10 local points.
        let mut rng = Rng::seed_from(1);
        let (shards, xs) =
            planted_regression_shards(10, 10, 30, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let comps = make_compressors(10, 30, 1.0, true, &mut rng);
        let opts = MultiOptions {
            step: problem.stable_step(),
            iters: 300,
            domain: Domain::Unconstrained,
            batch: Some(5),
            participation: Participation::Full,
        };
        let trace = run(&problem, &comps, &vec![0.0; 30], Some(&xs), opts, &mut rng);
        let first = trace.records[3].value;
        let last = trace.final_value();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
    }

    #[test]
    fn partial_participation_still_converges() {
        // k-of-m randomized participation with heterogeneous budgets:
        // 4-of-10 workers per round, R_i ∈ {0.5, 1, 2, 4} cycled; the
        // quadratic objective must still make clear progress.
        let mut rng = Rng::seed_from(21);
        let (shards, xs) =
            planted_regression_shards(10, 10, 30, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let budgets = [0.5f32, 1.0, 2.0, 4.0];
        let comps: Vec<Box<dyn Compressor>> = (0..10)
            .map(|i| {
                Box::new(Ndsc::hadamard_dithered(30, budgets[i % 4], &mut rng))
                    as Box<dyn Compressor>
            })
            .collect();
        let opts = MultiOptions {
            step: problem.stable_step(),
            iters: 400,
            domain: Domain::Unconstrained,
            batch: Some(5),
            participation: Participation::KofM { k: 4 },
        };
        let trace = run(&problem, &comps, &vec![0.0; 30], Some(&xs), opts, &mut rng);
        let first = trace.records[3].value;
        let last = trace.final_value();
        assert!(last < 0.5 * first, "no convergence under 4-of-10: {first} -> {last}");
        // Per-round payload varies with the drawn subset but never
        // exceeds the sum of the k largest budgets; the participants
        // column reports the drawn k everywhere.
        let max_round = (0..4).map(|_| (30.0f32 * 4.0) as usize).sum::<usize>();
        assert!(trace.records.iter().all(|r| r.payload_bits <= max_round));
        assert!(trace.records.iter().all(|r| r.participants == 4));
    }

    #[test]
    fn consensus_is_mean_of_decoded() {
        // With lossless-ish budgets the consensus step approaches the true
        // average gradient: check the round-0 consensus against it.
        let mut rng = Rng::seed_from(2);
        let (shards, _) =
            planted_regression_shards(4, 20, 10, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let x = vec![0.1f32; 10];
        let mut want = vec![0.0f32; 10];
        problem.gradient(&x, &mut want);
        // High budget => tiny quantization error.
        let comps = make_compressors(4, 10, 16.0, true, &mut rng);
        let mut got = vec![0.0f32; 10];
        let mut g = vec![0.0f32; 10];
        for (i, shard) in problem.shards.iter().enumerate() {
            shard.gradient(&x, &mut g);
            let q = comps[i].decompress(&comps[i].compress(&g, &mut rng));
            for (o, &qi) in got.iter_mut().zip(&q) {
                *o += qi / 4.0;
            }
        }
        assert!(
            dist2(&got, &want) < 0.05 * (1.0 + crate::linalg::vecops::norm2(&want)),
            "consensus error {}",
            dist2(&got, &want)
        );
    }
}
