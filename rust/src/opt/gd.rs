//! Unquantized gradient descent — the `σ = (L−μ)/(L+μ)` reference of
//! Fig. 1b and the inner trajectory DGD-DEF tracks.
//!
//! Engine spec: `ExactGrad` oracle, constant step, no codec, no
//! feedback, last-iterate output. (The historical loop recorded **and**
//! stepped `iters + 1` times, so the spec runs `iters + 1` rounds with
//! no trailing record — bit-identical, see `rust/tests/test_engine.rs`.)

use crate::linalg::rng::Rng;
use crate::opt::engine::oracle::ExactGrad;
use crate::opt::engine::schedule::{optimal_sc_step, Schedule};
use crate::opt::engine::{Engine, OutputMode, Problem};
use crate::opt::objectives::DatasetObjective;
use crate::opt::Trace;

/// Options for plain GD.
#[derive(Clone, Copy, Debug)]
pub struct GdOptions {
    pub step: f32,
    pub iters: usize,
}

impl GdOptions {
    /// The paper's optimal step `α* = 2/(L+μ)` (Thm. 2) — single-sourced
    /// in [`crate::opt::engine::schedule`].
    pub fn optimal(l: f32, mu: f32, iters: usize) -> Self {
        GdOptions { step: optimal_sc_step(l, mu), iters }
    }
}

/// Run GD from `x0`; `x_star` (if known) populates `dist_to_opt`.
pub fn run(
    obj: &DatasetObjective,
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: GdOptions,
) -> Trace {
    // GD is deterministic: the spec draws nothing from this throwaway rng.
    let mut rng = Rng::seed_from(0);
    Engine::new(Problem::Single(obj), Schedule::Constant(opts.step), opts.iters + 1)
        .with_oracle(ExactGrad { obj })
        .with_output(OutputMode::LastIterate { trailing: false })
        .run(x0, x_star, &mut rng)
}

/// Worst-case linear rate of unquantized GD over `F_{μ,L}` with the
/// optimal step: `σ = (L−μ)/(L+μ)`.
pub fn sigma(l: f32, mu: f32) -> f32 {
    (l - mu) / (l + mu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::matvec;
    use crate::opt::objectives::Loss;

    fn planted_lsq(m: usize, n: usize, seed: u64) -> (DatasetObjective, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; m];
        matvec(&a, m, n, &xs, &mut b);
        (DatasetObjective::new(a, b, m, n, Loss::Square, 0.0), xs)
    }

    #[test]
    fn converges_linearly_at_sigma() {
        let (obj, xs) = planted_lsq(60, 10, 1);
        let (l, mu) = obj.smoothness_strong_convexity();
        let opts = GdOptions::optimal(l, mu, 120);
        let trace = run(&obj, &vec![0.0; 10], Some(&xs), opts);
        let rate = trace.empirical_rate();
        let s = sigma(l, mu);
        assert!(rate <= s + 0.02, "empirical {rate} vs sigma {s}");
        assert!(trace.records.last().unwrap().dist_to_opt < 1e-2);
    }

    #[test]
    fn value_monotone_under_small_step() {
        let (obj, _) = planted_lsq(40, 8, 2);
        let (l, _) = obj.smoothness_strong_convexity();
        let trace = run(&obj, &vec![0.5; 8], None, GdOptions { step: 1.0 / l, iters: 50 });
        for w in trace.records.windows(2) {
            assert!(w[1].value <= w[0].value + 1e-5);
        }
    }

    #[test]
    fn record_and_step_count_match_the_legacy_loop() {
        // The legacy loop ran `0..=iters`: iters+1 records, iters+1 steps.
        let (obj, _) = planted_lsq(20, 5, 3);
        let trace = run(&obj, &vec![0.1; 5], None, GdOptions { step: 1e-3, iters: 10 });
        assert_eq!(trace.records.len(), 11);
        assert!(trace.records.iter().all(|r| r.payload_bits == 0));
    }
}
