//! Feedback memory — the per-worker state an algorithm threads between
//! rounds on the worker side.
//!
//! DGD-DEF (Alg. 1) keeps the quantization error `e_i` and uses it three
//! ways per round: shift the oracle query point (`z = x̂ + α·e_i`, the
//! App. D invariant that makes `z` track the unquantized trajectory),
//! pre-correct the gradient before encoding (`u = g − e_i`), and update
//! from the decoded estimate (`e_i = q − u`). DQ-PSGD needs none of this
//! — the dither's unbiasedness substitutes for feedback — so its memory
//! is [`NoFeedback`].

/// Per-worker feedback memory, called by the engine at three points of
/// each participant's round. A worker that does not participate in a
/// round (or whose frame is dropped by a lossy uplink) gets **no** calls:
/// its memory carries over unchanged — the feedback loop pauses, exactly
/// as the legacy multi-DEF loop behaved under k-of-m participation.
///
/// **Cross-worker independence contract.** `shift_point(i, ..)` and
/// `pre_encode(i, ..)` must depend only on worker `i`'s slice of the
/// memory, and `post_decode(i, ..)` must write only worker `i`'s slice.
/// The threaded round executor
/// ([`RunState::step_mt`](super::RunState::step_mt)) relies on this: it
/// runs every participant's shift/query/pre-encode phase concurrently
/// (through `&self`) before any `post_decode` runs, which is
/// order-equivalent to the inline interleaving *only* under this
/// contract. Both memories here satisfy it (`DefFeedback` keeps one
/// `e_i` per worker; `NoFeedback` has no state at all). The `Send +
/// Sync` supertraits are what let the executor share the memory across
/// scoped worker threads.
pub trait FeedbackMemory: Send + Sync {
    /// Compute worker `i`'s oracle query point from the broadcast iterate
    /// `x` and the round's step `α`, writing into `z`. Return `true` if
    /// `z` was written (the engine queries the oracle at `z`), `false`
    /// to query at `x` directly.
    fn shift_point(&self, i: usize, x: &[f32], step: f32, z: &mut [f32]) -> bool;
    /// Transform the raw gradient (in `g`) into the vector to encode.
    /// Takes `&self` (reading only worker `i`'s state) so the threaded
    /// executor can run all participants' encode phases concurrently.
    fn pre_encode(&self, i: usize, g: &mut [f32]);
    /// Observe the decoded estimate `q` of the encoded vector `u`;
    /// update the memory. Only called when the frame was delivered.
    fn post_decode(&mut self, i: usize, q: &[f32], u: &[f32]);

    /// Append this memory's checkpointable state to `out` as a flat f32
    /// stream ([`crate::serve::checkpoint`] serializes it). Stateless
    /// memories append nothing.
    fn save_state(&self, out: &mut Vec<f32>) {
        let _ = out;
    }

    /// Restore from exactly the floats [`FeedbackMemory::save_state`]
    /// wrote. Returns `false` on a shape mismatch (corrupt snapshot) —
    /// the memory is left unspecified in that case and the caller must
    /// discard it.
    fn restore_state(&mut self, data: &[f32]) -> bool {
        data.is_empty()
    }
}

/// No memory: plain (dithered) quantized descent.
pub struct NoFeedback;

impl FeedbackMemory for NoFeedback {
    fn shift_point(&self, _i: usize, _x: &[f32], _step: f32, _z: &mut [f32]) -> bool {
        false
    }

    fn pre_encode(&self, _i: usize, _g: &mut [f32]) {}

    fn post_decode(&mut self, _i: usize, _q: &[f32], _u: &[f32]) {}
}

/// Democratically-encoded error feedback (Alg. 1; per-worker in the
/// §4.3 extension): worker `i` owns `e_i`, initialized to zero.
pub struct DefFeedback {
    errs: Vec<Vec<f32>>,
}

impl DefFeedback {
    /// One zeroed error vector per worker (`e_{−1} = 0`).
    pub fn new(workers: usize, n: usize) -> Self {
        DefFeedback { errs: vec![vec![0.0f32; n]; workers] }
    }

    /// Worker `i`'s current error term (tests / invariant checks).
    pub fn error(&self, i: usize) -> &[f32] {
        &self.errs[i]
    }
}

impl FeedbackMemory for DefFeedback {
    fn shift_point(&self, i: usize, x: &[f32], step: f32, z: &mut [f32]) -> bool {
        // z = x̂ + α·e_i
        for ((zi, &xi), &ei) in z.iter_mut().zip(x).zip(&self.errs[i]) {
            *zi = xi + step * ei;
        }
        true
    }

    fn pre_encode(&self, i: usize, g: &mut [f32]) {
        // u = ∇f(z) − e_i (reads only worker i's slice — see the trait's
        // cross-worker independence contract)
        for (gi, &ei) in g.iter_mut().zip(&self.errs[i]) {
            *gi -= ei;
        }
    }

    fn post_decode(&mut self, i: usize, q: &[f32], u: &[f32]) {
        // e_i = q − u
        for ((ei, &qi), &ui) in self.errs[i].iter_mut().zip(q).zip(u) {
            *ei = qi - ui;
        }
    }

    fn save_state(&self, out: &mut Vec<f32>) {
        for e in &self.errs {
            out.extend_from_slice(e);
        }
    }

    fn restore_state(&mut self, data: &[f32]) -> bool {
        let per = self.errs.first().map(|e| e.len()).unwrap_or(0);
        if data.len() != per * self.errs.len() {
            return false;
        }
        for (i, e) in self.errs.iter_mut().enumerate() {
            e.copy_from_slice(&data[i * per..(i + 1) * per]);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_feedback_never_shifts() {
        let f = NoFeedback;
        let mut z = vec![9.0f32; 3];
        assert!(!f.shift_point(0, &[1.0, 2.0, 3.0], 0.5, &mut z));
        assert_eq!(z, vec![9.0; 3], "z must be untouched");
    }

    #[test]
    fn def_round_trip_updates_error() {
        let mut f = DefFeedback::new(2, 3);
        let x = [1.0f32, 2.0, 3.0];
        let mut z = vec![0.0f32; 3];
        // e = 0 ⇒ z == x.
        assert!(f.shift_point(1, &x, 0.5, &mut z));
        assert_eq!(z, x.to_vec());
        // Encode u = g − e = g; decode q; e = q − u.
        let mut g = vec![2.0f32, -1.0, 0.5];
        f.pre_encode(1, &mut g);
        let u = g.clone();
        let q = vec![1.5f32, -1.0, 1.0];
        f.post_decode(1, &q, &u);
        assert_eq!(f.error(1).to_vec(), vec![-0.5, 0.0, 0.5]);
        // Worker 0's memory is untouched.
        assert_eq!(f.error(0).to_vec(), vec![0.0, 0.0, 0.0]);
        // Next shift uses the updated error: z = x + 2·e.
        f.shift_point(1, &x, 2.0, &mut z);
        assert_eq!(z, vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn def_state_roundtrips_and_rejects_bad_shapes() {
        let mut f = DefFeedback::new(2, 3);
        f.post_decode(0, &[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]);
        f.post_decode(1, &[-1.0, 0.0, 1.0], &[0.0, 0.0, 0.0]);
        let mut saved = Vec::new();
        f.save_state(&mut saved);
        assert_eq!(saved.len(), 6);
        let mut g = DefFeedback::new(2, 3);
        assert!(g.restore_state(&saved));
        assert_eq!(g.error(0), f.error(0));
        assert_eq!(g.error(1), f.error(1));
        assert!(!g.restore_state(&saved[..5]), "short state must be rejected");
        // The stateless memory accepts only the empty stream.
        let mut none = NoFeedback;
        none.save_state(&mut Vec::new());
        assert!(none.restore_state(&[]));
        assert!(!none.restore_state(&[1.0]));
    }
}
