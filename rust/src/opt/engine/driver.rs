//! Drivers — where an engine spec's rounds execute.
//!
//! * [`InlineDriver`] — every round runs in the calling thread; the
//!   deterministic reference used by the figure harness and the theory
//!   tests. This is [`Engine::run`] behind a `Driver` face.
//! * [`CoordinatorDriver`] — rounds run on the threaded parameter server
//!   over a pluggable transport (in-process channels, SimNet, recorded
//!   traces), with per-worker budget enforcement and full / k-of-m /
//!   deadline participation from the [`RunConfig`]. The declarative side
//!   of the spec (scheme, budgets, participation, transport, rounds,
//!   step) lives in the config — the PR 3 transport layer owns delivery —
//!   while the [`Engine`] contributes the sharded problem and the
//!   initial iterate.
//!
//! [`run_config`] is the shared plumbing both the CLI (`repro train`) and
//! the sweep harness (`repro net`) call: it builds one gradient source
//! and one budget-`R_i` compressor per shard and drives
//! [`crate::coordinator::run_distributed`].

use crate::coordinator::config::RunConfig;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::run_distributed;
use crate::coordinator::worker::{DatasetGradSource, GradSource};
use crate::linalg::rng::Rng;
use crate::linalg::vecops::dist2;
use crate::opt::engine::{Engine, Problem};
use crate::opt::objectives::DatasetObjective;
use crate::opt::{IterRecord, Trace};

/// Executes an engine spec end to end.
pub trait Driver {
    /// Driver name for run summaries.
    fn name(&self) -> &'static str;
    /// Run `spec` from `x0`; `x_star` (when known) populates
    /// distance-to-optimum metrics where the driver can compute them.
    fn drive(
        &mut self,
        spec: Engine<'_>,
        x0: &[f32],
        x_star: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Trace;
}

/// The single-node inline driver.
pub struct InlineDriver;

impl Driver for InlineDriver {
    fn name(&self) -> &'static str {
        "inline"
    }

    fn drive(
        &mut self,
        spec: Engine<'_>,
        x0: &[f32],
        x_star: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Trace {
        spec.run(x0, x_star, rng)
    }
}

/// The distributed driver: re-hosts a sharded spec on the threaded
/// coordinator. Requires [`Problem::Sharded`] with one shard per
/// configured worker.
///
/// **The [`RunConfig`] is authoritative for the fleet**: codecs at
/// per-worker budgets `R_i`, batching, participation, transport, step.
/// The [`Engine`] contributes the problem, the initial iterate and the
/// round count — inline-only components of the spec (oracles, codecs,
/// schedule, feedback, drop-prob) are **not** translated onto the wire
/// and must be expressed through the config instead; `drive` asserts the
/// shapes that can be checked (`n`, `workers`, `rounds`) so a spec/config
/// mismatch fails loudly rather than running the wrong experiment.
pub struct CoordinatorDriver<'c> {
    pub cfg: &'c RunConfig,
    /// Per-worker gradient-noise salt: worker `i` samples minibatches
    /// from `Rng::seed_from(cfg.seed ^ (salt + i))`.
    pub worker_seed_salt: u64,
    /// How many serve fleets are live in this process (1 = solo run).
    /// When more than one fleet shares the process, [`Driver::drive`]
    /// disables the server's scoped-thread decode fan-out (the config is
    /// cloned with `parallel_decode_min_dim = usize::MAX`): the cluster
    /// already spends the process's thread budget on fleet and worker
    /// threads, and nesting a per-participant decode fan-out inside them
    /// would oversubscribe cores — the never-nest rule
    /// ([`crate::coordinator::config::FLEET_MAX_WORKER_THREADS`]).
    /// Decode results are bit-identical either way (accumulation is in
    /// worker-id order), so this only ever affects wall-clock.
    pub active_fleets: usize,
    /// Full metrics of the most recent [`Driver::drive`] call — wall
    /// clock, participants, budget rejections — beyond what a [`Trace`]
    /// carries.
    pub last_metrics: Option<RunMetrics>,
}

impl<'c> CoordinatorDriver<'c> {
    pub fn new(cfg: &'c RunConfig) -> Self {
        CoordinatorDriver { cfg, worker_seed_salt: 7, active_fleets: 1, last_metrics: None }
    }

    /// Declare how many serve fleets share this process (see
    /// [`CoordinatorDriver::active_fleets`]).
    pub fn with_active_fleets(mut self, fleets: usize) -> Self {
        self.active_fleets = fleets.max(1);
        self
    }
}

impl Driver for CoordinatorDriver<'_> {
    fn name(&self) -> &'static str {
        "coordinator"
    }

    fn drive(
        &mut self,
        spec: Engine<'_>,
        x0: &[f32],
        x_star: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Trace {
        let problem = match spec.problem() {
            Problem::Sharded(p) => p,
            Problem::Single(_) => {
                panic!("CoordinatorDriver needs a sharded problem (one shard per worker)")
            }
        };
        assert_eq!(self.cfg.n, problem.n, "config n != problem dimension");
        assert_eq!(self.cfg.workers, problem.m(), "config workers != shard count");
        assert_eq!(
            self.cfg.rounds,
            spec.rounds(),
            "config rounds != spec rounds (the coordinator runs the config's fleet; \
             build the spec with cfg.rounds)"
        );
        // Never-nest: with other fleets live in the process, keep the
        // decode single-threaded (bit-identical; see `active_fleets`).
        let clamped;
        let cfg = if self.active_fleets > 1 {
            clamped = RunConfig { parallel_decode_min_dim: usize::MAX, ..self.cfg.clone() };
            &clamped
        } else {
            self.cfg
        };
        let metrics = run_config(
            cfg,
            x0.to_vec(),
            problem.shards.clone(),
            self.worker_seed_salt,
            rng,
            |x| problem.value(x),
        );
        let mut trace = trace_from_metrics(&metrics);
        if let (Some(xs), Some(last)) = (x_star, trace.records.last_mut()) {
            last.dist_to_opt = dist2(&metrics.final_iterate, xs);
        }
        self.last_metrics = Some(metrics);
        trace
    }
}

/// Drive the threaded coordinator from a [`RunConfig`] and a set of
/// dataset shards: builds one compressor per worker at its own budget
/// `R_i` (frame randomness drawn from `rng` — the common randomness
/// established at setup) and one minibatch gradient source per shard
/// (noise stream `cfg.seed ^ (worker_seed_salt + i)`), then runs the
/// full transport-backed parameter server.
pub fn run_config(
    cfg: &RunConfig,
    x0: Vec<f32>,
    shards: Vec<DatasetObjective>,
    worker_seed_salt: u64,
    rng: &mut Rng,
    eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    assert_eq!(shards.len(), cfg.workers, "one shard per configured worker");
    let compressors = cfg.build_compressors(rng);
    let sources: Vec<Box<dyn GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: cfg.batch,
                rng: Rng::seed_from(cfg.seed ^ (worker_seed_salt + i as u64)),
                idx: Vec::new(),
            }) as Box<dyn GradSource>
        })
        .collect();
    run_distributed(cfg, x0, sources, compressors, eval)
}

/// View coordinator metrics as an optimizer [`Trace`] so both drivers
/// feed one consumer surface. Per-round distance-to-optimum is unknown
/// to the coordinator (records carry `NaN`); the final iterate and the
/// traffic totals transfer exactly.
pub fn trace_from_metrics(metrics: &RunMetrics) -> Trace {
    let mut trace = Trace {
        records: Vec::with_capacity(metrics.rounds.len()),
        final_x: metrics.final_iterate.clone(),
        total_payload_bits: metrics.total_payload_bits,
        total_side_bits: metrics.total_overhead_bits,
    };
    for r in &metrics.rounds {
        trace.records.push(IterRecord {
            value: r.value,
            dist_to_opt: f32::NAN,
            payload_bits: r.payload_bits,
            participants: r.participants,
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeKind;
    use crate::data::synthetic::planted_regression_shards;
    use crate::opt::engine::schedule::Schedule;
    use crate::opt::engine::oracle::ShardOracle;
    use crate::opt::engine::{OutputMode, RngPolicy};
    use crate::opt::multi::ShardedProblem;
    use crate::opt::objectives::Loss;

    #[test]
    fn coordinator_driver_runs_a_sharded_spec() {
        let n = 16;
        let m = 3;
        let mut rng = Rng::seed_from(9);
        let (shards, _) = planted_regression_shards(m, 8, n, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let cfg = RunConfig {
            n,
            workers: m,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 12,
            step: 1e-3,
            batch: 0,
            seed: 5,
            ..Default::default()
        };
        let spec = Engine::new(Problem::Sharded(&problem), Schedule::Constant(cfg.step), cfg.rounds)
            .with_output(OutputMode::PolyakAverage);
        let mut driver = CoordinatorDriver::new(&cfg);
        let xs = vec![0.0f32; n];
        let trace = driver.drive(spec, &vec![0.0; n], Some(&xs), &mut rng);
        assert_eq!(driver.name(), "coordinator");
        assert_eq!(trace.records.len(), 12);
        assert!(trace.final_x.iter().all(|v| v.is_finite()));
        assert!(trace.total_payload_bits > 0);
        assert!(trace.records.iter().all(|r| r.participants == m));
        assert!(trace.records.last().unwrap().dist_to_opt.is_finite());
        let metrics = driver.last_metrics.as_ref().expect("metrics stashed");
        assert_eq!(metrics.rounds.len(), 12);
        assert_eq!(metrics.total_payload_bits, trace.total_payload_bits);
    }

    #[test]
    fn active_fleets_clamp_is_trace_neutral() {
        // Force the threaded decode path on (min_dim 1), then check that
        // the never-nest clamp (active_fleets > 1 ⇒ inline decode)
        // changes nothing but the thread layout.
        let n = 16;
        let m = 3;
        let mut rng = Rng::seed_from(9);
        let (shards, _) = planted_regression_shards(m, 8, n, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let cfg = RunConfig {
            n,
            workers: m,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 8,
            step: 1e-3,
            batch: 0,
            seed: 5,
            parallel_decode_min_dim: 1,
            ..Default::default()
        };
        let run = |fleets: usize| {
            let spec =
                Engine::new(Problem::Sharded(&problem), Schedule::Constant(cfg.step), cfg.rounds)
                    .with_output(OutputMode::PolyakAverage);
            let mut d = CoordinatorDriver::new(&cfg).with_active_fleets(fleets);
            let mut r = Rng::seed_from(42);
            d.drive(spec, &vec![0.0; n], None, &mut r)
        };
        let solo = run(1);
        let clustered = run(4);
        assert_eq!(solo.final_x, clustered.final_x);
        assert_eq!(solo.total_payload_bits, clustered.total_payload_bits);
        assert_eq!(solo.records.len(), clustered.records.len());
        for (a, b) in solo.records.iter().zip(&clustered.records) {
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
    }

    #[test]
    fn inline_driver_is_engine_run() {
        let mut rng_a = Rng::seed_from(3);
        let mut rng_b = Rng::seed_from(3);
        let (shards, _) = {
            let mut data_rng = Rng::seed_from(1);
            planted_regression_shards(2, 6, 8, Loss::Square, &mut data_rng, false)
        };
        let problem = ShardedProblem::new(shards);
        let build = || {
            let mut e = Engine::new(Problem::Sharded(&problem), Schedule::Constant(1e-3), 10)
                .with_output(OutputMode::PolyakAverage)
                .with_rng_policy(RngPolicy::ForkPerWorker);
            for shard in &problem.shards {
                e = e.with_oracle(ShardOracle::new(shard, None));
            }
            e
        };
        let a = build().run(&vec![0.0; 8], None, &mut rng_a);
        let b = InlineDriver.drive(build(), &vec![0.0; 8], None, &mut rng_b);
        assert_eq!(a.final_x, b.final_x);
        assert_eq!(a.records.len(), b.records.len());
    }
}
