//! The optimizer engine — one round driver behind every algorithm.
//!
//! The paper's algorithms share a single round skeleton: *oracle call →
//! (feedback-corrected) compress → wire → decode → consensus → step*.
//! The engine implements that skeleton **once**, parameterized by four
//! pluggable pieces:
//!
//! | Trait | What it decides | Implementations |
//! |---|---|---|
//! | [`oracle::Oracle`] | worker-side gradient access | [`oracle::ExactGrad`], [`oracle::ShardOracle`], [`oracle::OwnNoise`] |
//! | [`schedule::StepSchedule`] | the step size `α_t` | [`schedule::Schedule`] (constant / `1/√t` / harmonic) |
//! | [`feedback::FeedbackMemory`] | per-worker round-to-round state | [`feedback::NoFeedback`], [`feedback::DefFeedback`] |
//! | [`driver::Driver`] | where rounds execute | [`driver::InlineDriver`], [`driver::CoordinatorDriver`] |
//!
//! The six legacy entry points are spec-builders over the engine — each
//! is one composition (`rust/tests/test_engine.rs` proves every one
//! bit-identical to its pre-engine loop):
//!
//! | Legacy `run()` | Composition |
//! |---|---|
//! | [`crate::opt::gd`] | `ExactGrad + Constant + NoFeedback`, no codec, last-iterate |
//! | [`crate::opt::psgd`] | `OwnNoise + Constant + NoFeedback`, no codec, Polyak average |
//! | [`crate::opt::dgd_def`] | `ExactGrad + Constant + DefFeedback`, shared codec, last-iterate |
//! | [`crate::opt::dq_psgd`] | `OwnNoise + Constant + NoFeedback`, shared dithered codec, drop-prob uplink, Polyak average |
//! | [`crate::opt::multi`] | `ShardOracle × m + Constant + NoFeedback`, per-worker codecs, forked RNGs, participation, Polyak average |
//! | [`crate::opt::multi_def`] | `ExactGrad × m + Constant + DefFeedback`, per-worker codecs, participation, last-iterate |
//!
//! A new algorithm is a new combination, not a new file: e.g. adaptive
//! precision is `with_schedule(Schedule::InvSqrt { .. })` on any spec,
//! and a lossy multi-worker uplink is `with_drop_prob(p)` on the `multi`
//! spec. This is the codebase's standing invariant.
//!
//! Determinism contract: the engine consumes randomness in a fixed order
//! — participation draw (shared RNG), then per participant in worker-id
//! order: batch draw, codec dither, drop verdict (worker RNG per
//! [`RngPolicy`]) — so traces are seed-deterministic and bit-stable
//! across refactors. Steady-state rounds are allocation-free
//! (`rust/tests/test_engine.rs` proves it with a counting allocator).

pub mod driver;
pub mod feedback;
pub mod oracle;
pub mod schedule;

use crate::coordinator::transport::Participation;
use crate::linalg::rng::Rng;
use crate::linalg::vecops::dist2;
use crate::opt::multi::ShardedProblem;
use crate::opt::objectives::DatasetObjective;
use crate::opt::projection::Domain;
use crate::opt::{IterRecord, Trace};
use crate::quant::{Compressed, Compressor, Workspace};

use self::feedback::{FeedbackMemory, NoFeedback};
use self::oracle::Oracle;
use self::schedule::StepSchedule;

/// What the engine optimizes: one objective, or one private shard per
/// worker with the global objective their average.
#[derive(Clone, Copy)]
pub enum Problem<'a> {
    Single(&'a DatasetObjective),
    Sharded(&'a ShardedProblem),
}

impl<'a> Problem<'a> {
    pub fn dim(&self) -> usize {
        match *self {
            Problem::Single(obj) => obj.dim(),
            Problem::Sharded(p) => p.n,
        }
    }

    /// Global objective value (the quantity every record reports).
    pub fn value(&self, x: &[f32]) -> f32 {
        match *self {
            Problem::Single(obj) => obj.value(x),
            Problem::Sharded(p) => p.value(x),
        }
    }
}

/// The uplink codec layout.
#[derive(Clone, Copy)]
pub enum Codecs<'a> {
    /// Unquantized: the decoded estimate is the gradient itself and the
    /// payload is zero (the GD / PSGD references).
    None,
    /// Every worker encodes through one codec instance (single-worker
    /// algorithms).
    Shared(&'a dyn Compressor),
    /// Worker `i` owns `codecs[i]` — each with its own frame randomness
    /// and budget `R_i`.
    PerWorker(&'a [Box<dyn Compressor>]),
}

impl<'a> Codecs<'a> {
    fn get(&self, i: usize) -> Option<&'a dyn Compressor> {
        match *self {
            Codecs::None => None,
            Codecs::Shared(c) => Some(c),
            Codecs::PerWorker(v) => Some(v[i].as_ref()),
        }
    }
}

/// Which RNG stream a worker's batch draw / codec dither / drop verdict
/// come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RngPolicy {
    /// The run's shared RNG, consumed in participant order — the
    /// single-worker loops' (and multi-DEF's) convention.
    Shared,
    /// Worker `i` draws from `rng.fork(i)`, forked once at startup — the
    /// multi-worker convention matching the threaded coordinator, where
    /// scheduling must not reorder draws.
    ForkPerWorker,
}

/// Trace shape: what each record reports and what `final_x` is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputMode {
    /// Record `f(x_t)` **before** each step; optionally append a trailing
    /// record after the final step. `final_x = x_T`. (GD, DGD-DEF,
    /// multi-DEF — the smooth strongly-convex algorithms.)
    LastIterate { trailing: bool },
    /// Polyak–Ruppert: maintain the running average of the projected
    /// iterates and record `f(x̄_t)` **after** each step;
    /// `final_x = x̄_T`. (PSGD, DQ-PSGD, multi — the averaged outputs.)
    PolyakAverage,
}

/// An engine spec: problem + the four pluggable components + round knobs.
/// Build with [`Engine::new`] and the `with_*` methods, then [`Engine::run`]
/// (the inline driver) or hand it to a [`driver::Driver`].
pub struct Engine<'a> {
    problem: Problem<'a>,
    oracles: Vec<Box<dyn Oracle + 'a>>,
    codecs: Codecs<'a>,
    schedule: Box<dyn StepSchedule + 'a>,
    feedback: Box<dyn FeedbackMemory + 'a>,
    domain: Domain,
    participation: Participation,
    drop_prob: f32,
    rng_policy: RngPolicy,
    output: OutputMode,
    rounds: usize,
    probe: Option<Box<dyn FnMut(usize) + 'a>>,
}

impl<'a> Engine<'a> {
    /// A spec with defaults: no oracles yet, no codec, no feedback,
    /// unconstrained domain, full participation, reliable uplink, shared
    /// RNG, last-iterate output with trailing record.
    pub fn new(problem: Problem<'a>, schedule: impl StepSchedule + 'a, rounds: usize) -> Self {
        Engine {
            problem,
            oracles: Vec::new(),
            codecs: Codecs::None,
            schedule: Box::new(schedule),
            feedback: Box::new(NoFeedback),
            domain: Domain::Unconstrained,
            participation: Participation::Full,
            drop_prob: 0.0,
            rng_policy: RngPolicy::Shared,
            output: OutputMode::LastIterate { trailing: true },
            rounds,
            probe: None,
        }
    }

    /// Append one worker's oracle (worker ids follow insertion order).
    pub fn with_oracle(mut self, o: impl Oracle + 'a) -> Self {
        self.oracles.push(Box::new(o));
        self
    }

    pub fn with_codecs(mut self, c: Codecs<'a>) -> Self {
        self.codecs = c;
        self
    }

    pub fn with_feedback(mut self, f: impl FeedbackMemory + 'a) -> Self {
        self.feedback = Box::new(f);
        self
    }

    pub fn with_domain(mut self, d: Domain) -> Self {
        self.domain = d;
        self
    }

    pub fn with_participation(mut self, p: Participation) -> Self {
        self.participation = p;
        self
    }

    /// Lossy uplink: each participant's frame is lost independently with
    /// this probability (bits still charged; the feedback memory of a
    /// lost frame pauses). Legacy open-range semantics: `p ≤ 0` is a
    /// reliable link and draws no randomness, `p ≥ 1` loses every frame
    /// (the all-drops degenerate case is a valid experiment).
    pub fn with_drop_prob(mut self, p: f32) -> Self {
        self.drop_prob = p;
        self
    }

    pub fn with_rng_policy(mut self, p: RngPolicy) -> Self {
        self.rng_policy = p;
        self
    }

    pub fn with_output(mut self, o: OutputMode) -> Self {
        self.output = o;
        self
    }

    /// Called after every completed round with the round index —
    /// progress reporting, allocation probes. Must not itself allocate if
    /// the run is measured for allocation-freedom.
    pub fn with_probe(mut self, p: impl FnMut(usize) + 'a) -> Self {
        self.probe = Some(Box::new(p));
        self
    }

    /// The spec's problem (drivers that re-host the run need it).
    pub fn problem(&self) -> Problem<'a> {
        self.problem
    }

    /// Configured round count.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Worker count (= registered oracles).
    pub fn workers(&self) -> usize {
        self.oracles.len()
    }

    /// Run the spec on the inline driver: every round executes in the
    /// calling thread, deterministically. See the module docs for the
    /// RNG-consumption contract; after warm-up, rounds are
    /// allocation-free.
    pub fn run(mut self, x0: &[f32], x_star: Option<&[f32]>, rng: &mut Rng) -> Trace {
        let n = self.problem.dim();
        let m = self.oracles.len();
        assert!(m >= 1, "engine spec has no worker oracle");
        assert_eq!(x0.len(), n, "x0 dimension mismatch");
        if let Codecs::PerWorker(v) = self.codecs {
            assert_eq!(v.len(), m, "one codec per worker");
        }
        for i in 0..m {
            assert_eq!(self.oracles[i].dim(), n, "oracle {i} dimension mismatch");
            if let Some(c) = self.codecs.get(i) {
                assert_eq!(c.n(), n, "codec {i} dimension mismatch");
            }
        }
        let averaging = self.output == OutputMode::PolyakAverage;

        let mut x = x0.to_vec();
        self.domain.project(&mut x);
        let mut avg = vec![0.0f32; if averaging { n } else { 0 }];
        let mut consensus = vec![0.0f32; n];
        let mut g = vec![0.0f32; n];
        let mut z = vec![0.0f32; n];
        let mut q = vec![0.0f32; n];
        let mut participants: Vec<usize> = Vec::with_capacity(m);
        // Forked per-worker streams are derived once, up front, in worker
        // id order (the coordinator's convention).
        let mut worker_rngs: Vec<Rng> = match self.rng_policy {
            RngPolicy::ForkPerWorker => (0..m).map(|i| rng.fork(i as u64)).collect(),
            RngPolicy::Shared => Vec::new(),
        };
        // One workspace + message shell + decode buffer serve all m
        // workers (every codec of a round has the same dimension), so
        // steady-state rounds allocate nothing.
        let mut ws = match self.codecs.get(0) {
            Some(c) => Workspace::for_compressor(c),
            None => Workspace::new(),
        };
        let mut msg = Compressed::empty(n);

        let mut trace = Trace::default();
        trace.records.reserve(self.rounds + 1);
        for t in 0..self.rounds {
            let step = self.schedule.step(t);
            if !averaging {
                trace.records.push(IterRecord {
                    value: self.problem.value(&x),
                    dist_to_opt: x_star.map(|xs| dist2(&x, xs)).unwrap_or(f32::NAN),
                    payload_bits: 0,
                    participants: 0,
                });
            }
            // Participant set. Full participation draws no randomness;
            // KofM samples a uniform k-subset from the shared RNG and
            // processes it in worker-id order. Deadline degrades to Full
            // inline — there is no network here; the coordinator driver
            // is where deadlines bite.
            match self.participation {
                Participation::KofM { k } => {
                    rng.sample_indices_into(m, k.min(m), &mut participants);
                    participants.sort_unstable();
                }
                Participation::Full | Participation::Deadline { .. } => {
                    participants.clear();
                    participants.extend(0..m);
                }
            }
            let p = participants.len().max(1);
            consensus.fill(0.0);
            let mut round_bits = 0usize;
            let mut delivered = 0usize;
            for &i in &participants {
                let shifted = self.feedback.shift_point(i, &x, step, &mut z);
                let wrng: &mut Rng = match self.rng_policy {
                    RngPolicy::Shared => &mut *rng,
                    RngPolicy::ForkPerWorker => &mut worker_rngs[i],
                };
                let point: &[f32] = if shifted { &z } else { &x };
                self.oracles[i].query(point, wrng, &mut g);
                self.feedback.pre_encode(i, &mut g);
                let codec = self.codecs.get(i);
                if let Some(c) = codec {
                    c.compress_into(&g, wrng, &mut ws, &mut msg);
                    round_bits += msg.payload_bits;
                    trace.total_payload_bits += msg.payload_bits;
                    trace.total_side_bits += msg.side_bits;
                }
                // The frame may never reach the server — bits are charged
                // on send, not delivery. One verdict for both the
                // quantized and the unquantized (lossless-codec) path.
                let arrived = self.drop_prob <= 0.0 || wrng.uniform_f32() >= self.drop_prob;
                if arrived {
                    let estimate: &[f32] = match codec {
                        Some(c) => {
                            c.decompress_into(&msg, &mut ws, &mut q);
                            &q
                        }
                        None => &g, // lossless: q ≡ u, zero payload
                    };
                    self.feedback.post_decode(i, estimate, &g);
                    delivered += 1;
                    for (ci, &ei) in consensus.iter_mut().zip(estimate) {
                        *ci += ei / p as f32;
                    }
                }
            }
            // Server: step on the consensus mean, then project. A round
            // with nothing delivered takes no step (and no projection —
            // re-projecting can perturb a boundary iterate by an ulp).
            if delivered > 0 {
                for (xi, &ci) in x.iter_mut().zip(&consensus) {
                    *xi -= step * ci;
                }
                self.domain.project(&mut x);
            }
            if averaging {
                let w = 1.0 / (t + 1) as f32;
                for (ai, &xi) in avg.iter_mut().zip(&x) {
                    *ai += w * (xi - *ai);
                }
                trace.records.push(IterRecord {
                    value: self.problem.value(&avg),
                    dist_to_opt: x_star.map(|xs| dist2(&avg, xs)).unwrap_or(f32::NAN),
                    payload_bits: round_bits,
                    participants: delivered,
                });
            } else if let Some(r) = trace.records.last_mut() {
                r.payload_bits = round_bits;
                r.participants = delivered;
            }
            if let Some(probe) = self.probe.as_mut() {
                probe(t);
            }
        }
        if let OutputMode::LastIterate { trailing: true } = self.output {
            trace.records.push(IterRecord {
                value: self.problem.value(&x),
                dist_to_opt: x_star.map(|xs| dist2(&x, xs)).unwrap_or(f32::NAN),
                payload_bits: 0,
                participants: 0,
            });
        }
        trace.final_x = if averaging { avg } else { x };
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::oracle::ExactGrad;
    use super::schedule::Schedule;
    use super::*;
    use crate::linalg::vecops::matvec;
    use crate::opt::objectives::Loss;
    use crate::quant::ndsc::Ndsc;

    fn planted_lsq(m: usize, n: usize, seed: u64) -> (DatasetObjective, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; m];
        matvec(&a, m, n, &xs, &mut b);
        (DatasetObjective::new(a, b, m, n, Loss::Square, 0.0), xs)
    }

    #[test]
    fn unquantized_spec_converges_like_gd() {
        let (obj, xs) = planted_lsq(60, 10, 1);
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(2);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::Constant(schedule::optimal_sc_step(l, mu)),
            121,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_output(OutputMode::LastIterate { trailing: false })
        .run(&vec![0.0; 10], Some(&xs), &mut rng);
        assert_eq!(tr.records.len(), 121);
        assert!(tr.records.last().unwrap().dist_to_opt < 1e-2);
        assert_eq!(tr.total_payload_bits, 0);
        assert!(tr.records.iter().all(|r| r.participants <= 1));
    }

    #[test]
    fn quantized_feedback_spec_converges() {
        // The DGD-DEF composition, built directly on the engine API.
        let (obj, _) = planted_lsq(80, 16, 3);
        let xs = obj.quadratic_minimizer();
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(4);
        let c = Ndsc::hadamard(16, 6.0, &mut rng);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::Constant(schedule::optimal_sc_step(l, mu)),
            150,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_codecs(Codecs::Shared(&c))
        .with_feedback(feedback::DefFeedback::new(1, 16))
        .run(&vec![0.0; 16], Some(&xs), &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 1e-2 * d0, "no convergence: {d0} -> {dt}");
        assert_eq!(tr.records.len(), 151, "150 pre-step records + trailing");
        assert!(tr.total_payload_bits > 0);
    }

    #[test]
    fn decaying_schedule_is_a_one_line_change() {
        // The composition the engine unlocks: DGD-DEF machinery with an
        // O(1/√t) schedule — no new loop file required.
        let (obj, xs) = planted_lsq(60, 8, 5);
        let (l, _) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(6);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::InvSqrt { c: 1.0 / l },
            200,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_output(OutputMode::LastIterate { trailing: false })
        .run(&vec![0.0; 8], Some(&xs), &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 0.5 * d0, "decaying-step run made no progress: {d0} -> {dt}");
    }

    #[test]
    fn lossy_uplink_applies_to_unquantized_specs_too() {
        // drop ≥ 1 is the all-drops degenerate case (legacy open-range
        // semantics): no upload ever lands, so no step is ever taken —
        // on the unquantized path as much as on the quantized one.
        let (obj, _) = planted_lsq(20, 6, 9);
        let mut rng = Rng::seed_from(10);
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(0.1), 8)
            .with_oracle(ExactGrad { obj: &obj })
            .with_drop_prob(1.0)
            .with_output(OutputMode::LastIterate { trailing: false })
            .run(&vec![0.5; 6], None, &mut rng);
        assert!(tr.records.iter().all(|r| r.participants == 0));
        assert_eq!(tr.final_x, vec![0.5; 6]);
        // A partially lossy unquantized link: some rounds must drop.
        let mut rng = Rng::seed_from(11);
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(1e-3), 40)
            .with_oracle(ExactGrad { obj: &obj })
            .with_drop_prob(0.5)
            .with_output(OutputMode::LastIterate { trailing: false })
            .run(&vec![0.5; 6], None, &mut rng);
        assert!(tr.records.iter().any(|r| r.participants == 0));
        assert!(tr.records.iter().any(|r| r.participants == 1));
    }

    #[test]
    fn probe_sees_every_round() {
        let (obj, _) = planted_lsq(20, 4, 7);
        let mut rng = Rng::seed_from(8);
        let mut seen = Vec::new();
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(1e-3), 5)
            .with_oracle(ExactGrad { obj: &obj })
            .with_output(OutputMode::LastIterate { trailing: false })
            .with_probe(|t| seen.push(t))
            .run(&vec![0.0; 4], None, &mut rng);
        drop(tr);
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
