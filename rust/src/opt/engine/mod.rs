//! The optimizer engine — one round driver behind every algorithm.
//!
//! The paper's algorithms share a single round skeleton: *oracle call →
//! (feedback-corrected) compress → wire → decode → consensus → step*.
//! The engine implements that skeleton **once**, parameterized by four
//! pluggable pieces:
//!
//! | Trait | What it decides | Implementations |
//! |---|---|---|
//! | [`oracle::Oracle`] | worker-side gradient access | [`oracle::ExactGrad`], [`oracle::ShardOracle`], [`oracle::OwnNoise`] |
//! | [`schedule::StepSchedule`] | the step size `α_t` | [`schedule::Schedule`] (constant / `1/√t` / harmonic) |
//! | [`feedback::FeedbackMemory`] | per-worker round-to-round state | [`feedback::NoFeedback`], [`feedback::DefFeedback`] |
//! | [`driver::Driver`] | where rounds execute | [`driver::InlineDriver`], [`driver::CoordinatorDriver`] |
//!
//! The six legacy entry points are spec-builders over the engine — each
//! is one composition (`rust/tests/test_engine.rs` proves every one
//! bit-identical to its pre-engine loop):
//!
//! | Legacy `run()` | Composition |
//! |---|---|
//! | [`crate::opt::gd`] | `ExactGrad + Constant + NoFeedback`, no codec, last-iterate |
//! | [`crate::opt::psgd`] | `OwnNoise + Constant + NoFeedback`, no codec, Polyak average |
//! | [`crate::opt::dgd_def`] | `ExactGrad + Constant + DefFeedback`, shared codec, last-iterate |
//! | [`crate::opt::dq_psgd`] | `OwnNoise + Constant + NoFeedback`, shared dithered codec, drop-prob uplink, Polyak average |
//! | [`crate::opt::multi`] | `ShardOracle × m + Constant + NoFeedback`, per-worker codecs, forked RNGs, participation, Polyak average |
//! | [`crate::opt::multi_def`] | `ExactGrad × m + Constant + DefFeedback`, per-worker codecs, participation, last-iterate |
//!
//! A new algorithm is a new combination, not a new file: e.g. adaptive
//! precision is `with_schedule(Schedule::InvSqrt { .. })` on any spec,
//! and a lossy multi-worker uplink is `with_drop_prob(p)` on the `multi`
//! spec. This is the codebase's standing invariant.
//!
//! Determinism contract: the engine consumes randomness in a fixed order
//! — participation draw (shared RNG), then per participant in worker-id
//! order: batch draw, codec dither, drop verdict (worker RNG per
//! [`RngPolicy`]) — so traces are seed-deterministic and bit-stable
//! across refactors. Steady-state rounds are allocation-free
//! (`rust/tests/test_engine.rs` proves it with a counting allocator).
//!
//! **Re-entrancy.** A run is not a black box: [`Engine::start`] returns
//! an [`EngineRun`] that advances **one round per `step` call** and can
//! be suspended indefinitely between rounds — [`Engine::run`] is just
//! `start` + `step` to exhaustion + `finish`, bit-identical by
//! construction. One level lower, [`RunState`] owns every between-round
//! mutable buffer (iterate, Polyak average, scratch, forked worker RNGs,
//! trace) while [`RoundCtx`] borrows the pluggable components for the
//! duration of a single round. This split is what the multi-job serving
//! layer ([`crate::serve`]) is built on: a job owns its components and a
//! `RunState`, assembles a `RoundCtx` on the stack whenever the
//! scheduler grants it a round, and checkpoints by serializing the
//! `RunState` (plus RNG and feedback state) — rounds are
//! interleaving-independent because all cross-round state lives in the
//! job.
//!
//! **Threaded rounds.** [`RunState::step_mt`] is the scoped-thread twin
//! of [`RunState::step`] for the full-participation, forked-RNG
//! composition: the worker phase (shift → query → pre-encode → encode →
//! drop verdict → decode) fans out over worker threads with every
//! mutable value confined to a per-worker [`ChannelPools`]-recycled
//! slot, while the server phase (feedback `post_decode`, consensus
//! accumulation, step, projection) stays sequential in worker-id order —
//! so the result is bit-identical to the inline path for any thread
//! count (proven by `threaded_step_mt_is_bit_identical_to_inline_step`
//! and the serve suite's fleet-vs-solo oracles). The multi-fleet serving
//! layer ([`crate::serve::cluster`]) is its main client.

pub mod driver;
pub mod feedback;
pub mod oracle;
pub mod schedule;

use std::sync::Arc;

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::transport::Participation;
use crate::linalg::rng::Rng;
use crate::linalg::vecops::dist2;
use crate::opt::multi::ShardedProblem;
use crate::opt::objectives::DatasetObjective;
use crate::opt::projection::Domain;
use crate::opt::{IterRecord, Trace};
use crate::quant::{Compressed, Compressor, Workspace};

use self::feedback::{FeedbackMemory, NoFeedback};
use self::oracle::Oracle;
use self::schedule::StepSchedule;

/// What the engine optimizes: one objective, or one private shard per
/// worker with the global objective their average.
#[derive(Clone, Copy)]
pub enum Problem<'a> {
    Single(&'a DatasetObjective),
    Sharded(&'a ShardedProblem),
}

impl<'a> Problem<'a> {
    pub fn dim(&self) -> usize {
        match *self {
            Problem::Single(obj) => obj.dim(),
            Problem::Sharded(p) => p.n,
        }
    }

    /// Global objective value (the quantity every record reports).
    pub fn value(&self, x: &[f32]) -> f32 {
        match *self {
            Problem::Single(obj) => obj.value(x),
            Problem::Sharded(p) => p.value(x),
        }
    }
}

/// The uplink codec layout.
#[derive(Clone, Copy)]
pub enum Codecs<'a> {
    /// Unquantized: the decoded estimate is the gradient itself and the
    /// payload is zero (the GD / PSGD references).
    None,
    /// Every worker encodes through one codec instance (single-worker
    /// algorithms).
    Shared(&'a dyn Compressor),
    /// Worker `i` owns `codecs[i]` — each with its own frame randomness
    /// and budget `R_i`.
    PerWorker(&'a [Box<dyn Compressor>]),
}

impl<'a> Codecs<'a> {
    fn get(&self, i: usize) -> Option<&'a dyn Compressor> {
        match *self {
            Codecs::None => None,
            Codecs::Shared(c) => Some(c),
            Codecs::PerWorker(v) => Some(v[i].as_ref()),
        }
    }
}

/// Which RNG stream a worker's batch draw / codec dither / drop verdict
/// come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RngPolicy {
    /// The run's shared RNG, consumed in participant order — the
    /// single-worker loops' (and multi-DEF's) convention.
    Shared,
    /// Worker `i` draws from `rng.fork(i)`, forked once at startup — the
    /// multi-worker convention matching the threaded coordinator, where
    /// scheduling must not reorder draws.
    ForkPerWorker,
}

/// Trace shape: what each record reports and what `final_x` is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputMode {
    /// Record `f(x_t)` **before** each step; optionally append a trailing
    /// record after the final step. `final_x = x_T`. (GD, DGD-DEF,
    /// multi-DEF — the smooth strongly-convex algorithms.)
    LastIterate { trailing: bool },
    /// Polyak–Ruppert: maintain the running average of the projected
    /// iterates and record `f(x̄_t)` **after** each step;
    /// `final_x = x̄_T`. (PSGD, DQ-PSGD, multi — the averaged outputs.)
    PolyakAverage,
}

/// An engine spec: problem + the four pluggable components + round knobs.
/// Build with [`Engine::new`] and the `with_*` methods, then [`Engine::run`]
/// (the inline driver) or hand it to a [`driver::Driver`].
pub struct Engine<'a> {
    problem: Problem<'a>,
    oracles: Vec<Box<dyn Oracle + 'a>>,
    codecs: Codecs<'a>,
    schedule: Box<dyn StepSchedule + 'a>,
    feedback: Box<dyn FeedbackMemory + 'a>,
    domain: Domain,
    participation: Participation,
    drop_prob: f32,
    rng_policy: RngPolicy,
    output: OutputMode,
    rounds: usize,
    probe: Option<Box<dyn FnMut(usize) + 'a>>,
}

impl<'a> Engine<'a> {
    /// A spec with defaults: no oracles yet, no codec, no feedback,
    /// unconstrained domain, full participation, reliable uplink, shared
    /// RNG, last-iterate output with trailing record.
    pub fn new(problem: Problem<'a>, schedule: impl StepSchedule + 'a, rounds: usize) -> Self {
        Engine {
            problem,
            oracles: Vec::new(),
            codecs: Codecs::None,
            schedule: Box::new(schedule),
            feedback: Box::new(NoFeedback),
            domain: Domain::Unconstrained,
            participation: Participation::Full,
            drop_prob: 0.0,
            rng_policy: RngPolicy::Shared,
            output: OutputMode::LastIterate { trailing: true },
            rounds,
            probe: None,
        }
    }

    /// Append one worker's oracle (worker ids follow insertion order).
    pub fn with_oracle(mut self, o: impl Oracle + 'a) -> Self {
        self.oracles.push(Box::new(o));
        self
    }

    pub fn with_codecs(mut self, c: Codecs<'a>) -> Self {
        self.codecs = c;
        self
    }

    pub fn with_feedback(mut self, f: impl FeedbackMemory + 'a) -> Self {
        self.feedback = Box::new(f);
        self
    }

    pub fn with_domain(mut self, d: Domain) -> Self {
        self.domain = d;
        self
    }

    pub fn with_participation(mut self, p: Participation) -> Self {
        self.participation = p;
        self
    }

    /// Lossy uplink: each participant's frame is lost independently with
    /// this probability (bits still charged; the feedback memory of a
    /// lost frame pauses). Legacy open-range semantics: `p ≤ 0` is a
    /// reliable link and draws no randomness, `p ≥ 1` loses every frame
    /// (the all-drops degenerate case is a valid experiment).
    pub fn with_drop_prob(mut self, p: f32) -> Self {
        self.drop_prob = p;
        self
    }

    pub fn with_rng_policy(mut self, p: RngPolicy) -> Self {
        self.rng_policy = p;
        self
    }

    pub fn with_output(mut self, o: OutputMode) -> Self {
        self.output = o;
        self
    }

    /// Called after every completed round with the round index —
    /// progress reporting, allocation probes. Must not itself allocate if
    /// the run is measured for allocation-freedom.
    pub fn with_probe(mut self, p: impl FnMut(usize) + 'a) -> Self {
        self.probe = Some(Box::new(p));
        self
    }

    /// The spec's problem (drivers that re-host the run need it).
    pub fn problem(&self) -> Problem<'a> {
        self.problem
    }

    /// Configured round count.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Worker count (= registered oracles).
    pub fn workers(&self) -> usize {
        self.oracles.len()
    }

    /// Run the spec on the inline driver: every round executes in the
    /// calling thread, deterministically. See the module docs for the
    /// RNG-consumption contract; after warm-up, rounds are
    /// allocation-free. Equivalent to [`Engine::start`] + [`EngineRun::step`]
    /// to exhaustion + [`EngineRun::finish`].
    pub fn run(self, x0: &[f32], x_star: Option<&[f32]>, rng: &mut Rng) -> Trace {
        let mut run = self.start(x0, x_star, rng);
        while run.step(rng) {}
        run.finish()
    }

    /// Validate the spec shapes and set up a re-entrant [`EngineRun`]:
    /// the buffers are allocated, the per-worker RNG streams forked (this
    /// consumes `rng` exactly as the first moments of [`Engine::run`]
    /// do), and no round has executed yet.
    pub fn start(self, x0: &[f32], x_star: Option<&[f32]>, rng: &mut Rng) -> EngineRun<'a> {
        let n = self.problem.dim();
        let m = self.oracles.len();
        assert!(m >= 1, "engine spec has no worker oracle");
        assert_eq!(x0.len(), n, "x0 dimension mismatch");
        if let Codecs::PerWorker(v) = self.codecs {
            assert_eq!(v.len(), m, "one codec per worker");
        }
        for i in 0..m {
            assert_eq!(self.oracles[i].dim(), n, "oracle {i} dimension mismatch");
            if let Some(c) = self.codecs.get(i) {
                assert_eq!(c.n(), n, "codec {i} dimension mismatch");
            }
        }
        let st = RunState::new(
            x0,
            m,
            self.rounds,
            self.domain,
            self.rng_policy,
            self.output,
            self.codecs.get(0),
            rng,
        );
        EngineRun { x_star: x_star.map(|v| v.to_vec()), spec: self, st }
    }
}

// ---------------------------------------------------------------------------
// The re-entrant round machinery: RunState × RoundCtx.
// ---------------------------------------------------------------------------

/// The engine's view of "worker `i`'s oracle" for one round. The spec's
/// `Vec<Box<dyn Oracle>>` implements it; so does any structure that can
/// produce a gradient per worker index without owning trait objects —
/// the serving layer's jobs assemble one on the stack per round.
pub trait OracleBank {
    /// Number of workers in the bank.
    fn workers(&self) -> usize;
    /// Write worker `i`'s (sub)gradient estimate at `x` into `out`,
    /// drawing any batch randomness from `rng`. The bank guarantees the
    /// gradient dimension matches the run's (callers validate at setup:
    /// [`Engine::start`] asserts per-oracle dims, and a serve job's
    /// shards share one dimension by [`crate::opt::multi::ShardedProblem`]
    /// construction).
    fn query(&mut self, i: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]);
}

impl<'a> OracleBank for Vec<Box<dyn Oracle + 'a>> {
    fn workers(&self) -> usize {
        self.len()
    }

    fn query(&mut self, i: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        self[i].query(x, rng, out)
    }
}

/// An oracle bank whose queries can run **concurrently** — one scoped
/// worker thread per participant in [`RunState::step_mt`]. The bank is
/// shared (`&self`) across the threads, so implementors must keep all
/// per-query mutable state in the caller-provided scratch (`idx` for the
/// batch draw, `out` for the gradient): two threads querying different
/// workers must never race on bank-internal state. The serving layer's
/// `ShardBank` is the canonical implementation — the shards themselves
/// are read-only per round.
pub trait SharedOracleBank: OracleBank + Sync {
    /// Write worker `i`'s (sub)gradient at `x` into `out`, drawing batch
    /// randomness from `rng` with `idx` as index scratch. Must consume
    /// `rng` exactly as [`OracleBank::query`] does — `step_mt`'s
    /// bit-identity to `step` rests on it.
    fn query_shared(&self, i: usize, x: &[f32], rng: &mut Rng, idx: &mut Vec<usize>, out: &mut [f32]);
}

/// Borrowed view of the pluggable components for **one** round — built on
/// the stack by whoever owns the components ([`EngineRun::step`], or a
/// serving-layer job), handed to [`RunState::step`], and dropped when the
/// round completes. Nothing in here carries state between rounds; all of
/// that lives in [`RunState`].
pub struct RoundCtx<'c> {
    /// The objective the round reports values against.
    pub problem: Problem<'c>,
    /// Worker-side gradient access.
    pub oracles: &'c mut (dyn OracleBank + 'c),
    /// The uplink codec layout.
    pub codecs: Codecs<'c>,
    /// Step-size rule.
    pub schedule: &'c (dyn StepSchedule + 'c),
    /// Per-worker feedback memory.
    pub feedback: &'c mut (dyn FeedbackMemory + 'c),
    /// Projection domain.
    pub domain: Domain,
    /// Participant selection per round.
    pub participation: Participation,
    /// Lossy-uplink probability (see [`Engine::with_drop_prob`]).
    pub drop_prob: f32,
    /// Which RNG stream worker draws come from.
    pub rng_policy: RngPolicy,
    /// Total configured rounds (the run refuses to step past this).
    pub rounds: usize,
    /// Known minimizer for distance-to-optimum records.
    pub x_star: Option<&'c [f32]>,
}

/// [`RoundCtx`]'s threaded sibling: the component view
/// [`RunState::step_mt`] needs to run one round's worker phase on scoped
/// threads. Narrower on purpose — the threaded executor supports exactly
/// the multi-worker serving composition: **full** participation (every
/// worker, every round; no participation draw from the shared RNG, so
/// there is nothing to reorder) and **forked** per-worker RNG streams
/// ([`RngPolicy::ForkPerWorker`]; asserted at `step_mt` entry). The
/// oracle bank is shared (`Sync`), and the feedback memory is threaded
/// through under the cross-worker independence contract documented on
/// [`FeedbackMemory`].
pub struct MtRoundCtx<'c> {
    /// The objective the round reports values against.
    pub problem: Problem<'c>,
    /// Worker-side gradient access, shared across worker threads.
    pub oracles: &'c (dyn SharedOracleBank + 'c),
    /// The uplink codec layout.
    pub codecs: Codecs<'c>,
    /// Step-size rule.
    pub schedule: &'c (dyn StepSchedule + 'c),
    /// Per-worker feedback memory (worker phase borrows it shared; the
    /// sequential server phase gets it back mutably for `post_decode`).
    pub feedback: &'c mut (dyn FeedbackMemory + 'c),
    /// Projection domain.
    pub domain: Domain,
    /// Lossy-uplink probability (see [`Engine::with_drop_prob`]).
    pub drop_prob: f32,
    /// Total configured rounds (the run refuses to step past this).
    pub rounds: usize,
    /// Known minimizer for distance-to-optimum records.
    pub x_star: Option<&'c [f32]>,
}

/// Per-worker scratch for the threaded round executor: gradient / shift
/// / decode buffers, batch-index scratch, codec workspace and wire
/// message. Allocated once per run (the f32 buffers come from the
/// fleet's recycled [`ChannelPools`]) and reused every round, so
/// threaded steady-state rounds allocate nothing — the inline path's
/// standing invariant, per worker. Never serialized: a checkpoint
/// restores a run with no slots, and the first threaded round rebuilds
/// them.
struct WorkerSlot {
    g: Vec<f32>,
    z: Vec<f32>,
    q: Vec<f32>,
    idx: Vec<usize>,
    ws: Workspace,
    msg: Compressed,
    /// Whether this round's frame went through a codec (`msg` is live).
    encoded: bool,
    /// This round's drop verdict: did the frame reach the server?
    arrived: bool,
}

/// Every between-round mutable buffer of an engine run: the iterate, the
/// Polyak average, per-round scratch, forked worker RNG streams, and the
/// accumulated [`Trace`]. A `RunState` plus the job RNG plus the feedback
/// memory is the **complete** resumable state of a run — which is exactly
/// what [`crate::serve::checkpoint`] serializes.
pub struct RunState {
    pub(crate) t: usize,
    pub(crate) x: Vec<f32>,
    pub(crate) avg: Vec<f32>,
    consensus: Vec<f32>,
    g: Vec<f32>,
    z: Vec<f32>,
    q: Vec<f32>,
    participants: Vec<usize>,
    pub(crate) worker_rngs: Vec<Rng>,
    ws: Workspace,
    msg: Compressed,
    /// Threaded-executor scratch (one slot per worker); empty until the
    /// first [`RunState::step_mt`] and excluded from checkpoints.
    mt_slots: Vec<WorkerSlot>,
    pub(crate) trace: Trace,
    averaging: bool,
    finalized: bool,
}

impl RunState {
    /// Allocate the run buffers and fork the per-worker RNG streams (in
    /// worker-id order, consuming `rng` — the coordinator's convention).
    /// `codec0` sizes the shared workspace; one workspace + message shell
    /// + decode buffer serve all workers (every codec of a round has the
    /// same dimension), so steady-state rounds allocate nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x0: &[f32],
        workers: usize,
        rounds: usize,
        domain: Domain,
        rng_policy: RngPolicy,
        output: OutputMode,
        codec0: Option<&dyn Compressor>,
        rng: &mut Rng,
    ) -> RunState {
        let n = x0.len();
        let averaging = output == OutputMode::PolyakAverage;
        let mut x = x0.to_vec();
        domain.project(&mut x);
        let worker_rngs: Vec<Rng> = match rng_policy {
            RngPolicy::ForkPerWorker => (0..workers).map(|i| rng.fork(i as u64)).collect(),
            RngPolicy::Shared => Vec::new(),
        };
        let ws = match codec0 {
            Some(c) => Workspace::for_compressor(c),
            None => Workspace::new(),
        };
        let mut trace = Trace::default();
        trace.records.reserve(rounds + 1);
        RunState {
            t: 0,
            x,
            avg: vec![0.0f32; if averaging { n } else { 0 }],
            consensus: vec![0.0f32; n],
            g: vec![0.0f32; n],
            z: vec![0.0f32; n],
            q: vec![0.0f32; n],
            participants: Vec::with_capacity(workers),
            worker_rngs,
            ws,
            msg: Compressed::empty(n),
            mt_slots: Vec::new(),
            trace,
            averaging,
            finalized: false,
        }
    }

    /// Rounds executed so far.
    pub fn round(&self) -> usize {
        self.t
    }

    /// The trace accumulated so far (`final_x` is unset until
    /// [`RunState::finalize`]).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// The current iterate `x_t`.
    pub fn iterate(&self) -> &[f32] {
        &self.x
    }

    /// Whether [`RunState::finalize`] has run.
    pub fn is_finalized(&self) -> bool {
        self.finalized
    }

    /// Execute round `t` (0-based) and advance. Returns `false` — without
    /// touching any state or RNG — once `ctx.rounds` rounds have executed
    /// or the run was finalized. The RNG-consumption order is the module
    /// docs' determinism contract.
    pub fn step(&mut self, ctx: &mut RoundCtx<'_>, rng: &mut Rng) -> bool {
        if self.t >= ctx.rounds || self.finalized {
            return false;
        }
        let t = self.t;
        let m = ctx.oracles.workers();
        let step = ctx.schedule.step(t);
        self.open_round(ctx.problem, ctx.x_star);
        // Participant set. Full participation draws no randomness;
        // KofM samples a uniform k-subset from the shared RNG and
        // processes it in worker-id order. Deadline degrades to Full
        // inline — there is no network here; the coordinator driver
        // is where deadlines bite.
        match ctx.participation {
            Participation::KofM { k } => {
                rng.sample_indices_into(m, k.min(m), &mut self.participants);
                self.participants.sort_unstable();
            }
            Participation::Full | Participation::Deadline { .. } => {
                self.participants.clear();
                self.participants.extend(0..m);
            }
        }
        let p = self.participants.len().max(1);
        self.consensus.fill(0.0);
        let mut round_bits = 0usize;
        let mut delivered = 0usize;
        for &i in &self.participants {
            let shifted = ctx.feedback.shift_point(i, &self.x, step, &mut self.z);
            let wrng: &mut Rng = match ctx.rng_policy {
                RngPolicy::Shared => &mut *rng,
                RngPolicy::ForkPerWorker => &mut self.worker_rngs[i],
            };
            let point: &[f32] = if shifted { &self.z } else { &self.x };
            ctx.oracles.query(i, point, wrng, &mut self.g);
            ctx.feedback.pre_encode(i, &mut self.g);
            let codec = ctx.codecs.get(i);
            if let Some(c) = codec {
                c.compress_into(&self.g, wrng, &mut self.ws, &mut self.msg);
                round_bits += self.msg.payload_bits;
                self.trace.total_payload_bits += self.msg.payload_bits;
                self.trace.total_side_bits += self.msg.side_bits;
            }
            // The frame may never reach the server — bits are charged
            // on send, not delivery. One verdict for both the
            // quantized and the unquantized (lossless-codec) path.
            let arrived = ctx.drop_prob <= 0.0 || wrng.uniform_f32() >= ctx.drop_prob;
            if arrived {
                let estimate: &[f32] = match codec {
                    Some(c) => {
                        c.decompress_into(&self.msg, &mut self.ws, &mut self.q);
                        &self.q
                    }
                    None => &self.g, // lossless: q ≡ u, zero payload
                };
                ctx.feedback.post_decode(i, estimate, &self.g);
                delivered += 1;
                for (ci, &ei) in self.consensus.iter_mut().zip(estimate) {
                    *ci += ei / p as f32;
                }
            }
        }
        self.close_round(ctx.problem, ctx.domain, ctx.x_star, step, round_bits, delivered);
        true
    }

    /// The round preamble shared by [`RunState::step`] and
    /// [`RunState::step_mt`]: push the pre-step record when the output
    /// mode reports `f(x_t)` before stepping.
    fn open_round(&mut self, problem: Problem<'_>, x_star: Option<&[f32]>) {
        if !self.averaging {
            self.trace.records.push(IterRecord {
                value: problem.value(&self.x),
                dist_to_opt: x_star.map(|xs| dist2(&self.x, xs)).unwrap_or(f32::NAN),
                payload_bits: 0,
                participants: 0,
            });
        }
    }

    /// The round tail shared by [`RunState::step`] and
    /// [`RunState::step_mt`]: server step on the consensus mean, then
    /// project. A round with nothing delivered takes no step (and no
    /// projection — re-projecting can perturb a boundary iterate by an
    /// ulp). Then record (Polyak) or backfill (last-iterate) and advance.
    fn close_round(
        &mut self,
        problem: Problem<'_>,
        domain: Domain,
        x_star: Option<&[f32]>,
        step: f32,
        round_bits: usize,
        delivered: usize,
    ) {
        let t = self.t;
        if delivered > 0 {
            for (xi, &ci) in self.x.iter_mut().zip(&self.consensus) {
                *xi -= step * ci;
            }
            domain.project(&mut self.x);
        }
        if self.averaging {
            let w = 1.0 / (t + 1) as f32;
            for (ai, &xi) in self.avg.iter_mut().zip(&self.x) {
                *ai += w * (xi - *ai);
            }
            self.trace.records.push(IterRecord {
                value: problem.value(&self.avg),
                dist_to_opt: x_star.map(|xs| dist2(&self.avg, xs)).unwrap_or(f32::NAN),
                payload_bits: round_bits,
                participants: delivered,
            });
        } else if let Some(r) = self.trace.records.last_mut() {
            r.payload_bits = round_bits;
            r.participants = delivered;
        }
        self.t += 1;
    }

    /// Execute round `t` with the worker phase fanned out over at most
    /// `threads` scoped threads, **bit-identical** to [`RunState::step`]
    /// on the same state. Requires the threaded-executor composition:
    /// full participation (implied by [`MtRoundCtx`]) and
    /// [`RngPolicy::ForkPerWorker`] (asserted — worker RNG streams are
    /// what make per-worker draws scheduling-independent).
    ///
    /// Why the result cannot differ from the inline path:
    /// * every per-worker draw (batch, dither, drop verdict) comes from
    ///   that worker's own forked RNG, in the same in-stream order;
    /// * the shared job RNG is untouched (full participation draws
    ///   nothing from it — same as inline);
    /// * `shift_point`/`pre_encode` read only worker-local feedback
    ///   state (the [`FeedbackMemory`] contract), so running them before
    ///   any `post_decode` is order-equivalent to the interleaving;
    /// * the server phase — bit accounting, `post_decode`, the consensus
    ///   sum `Σ eᵢ/p` — runs sequentially in worker-id order, so the
    ///   float accumulation order is exactly the inline loop's.
    ///
    /// Workers are split into contiguous chunks of `⌈m/threads⌉`, so the
    /// thread *count* only changes which OS thread runs a worker, never
    /// what the worker computes. `threads ≤ 1` still goes through the
    /// slot machinery (one chunk, current thread) — same code path, no
    /// spawns.
    ///
    /// **Reentrancy under the serve layer's epoch pool** (desk-checked
    /// for PR 8): this method may be called concurrently from several
    /// cluster pool workers, each on a *different* job's state. That is
    /// sound because every mutable touch is confined to `self`, `ctx`,
    /// and this job's pool-checked-out slots (`ensure_mt_slots` goes
    /// through the `Mutex`-protected [`ChannelPools`], which is shared
    /// and thread-safe); the scoped threads spawned here nest under the
    /// never-nest cap because the fleet fan-out gate
    /// ([`crate::coordinator::config::fleet_fanout_threads`]) divides
    /// the budget by the cluster's **maximum** fleet count — exactly the
    /// number of pool workers that can run grants at once.
    pub fn step_mt(
        &mut self,
        ctx: &mut MtRoundCtx<'_>,
        threads: usize,
        pools: &Arc<ChannelPools>,
    ) -> bool {
        if self.t >= ctx.rounds || self.finalized {
            return false;
        }
        let m = ctx.oracles.workers();
        assert_eq!(
            self.worker_rngs.len(),
            m,
            "step_mt requires RngPolicy::ForkPerWorker (one RNG stream per worker)"
        );
        let t = self.t;
        let step = ctx.schedule.step(t);
        self.open_round(ctx.problem, ctx.x_star);
        self.ensure_mt_slots(m, ctx.codecs, pools);

        // Worker phase: shift → query → pre-encode → encode → drop
        // verdict → decode, each worker confined to its own slot + RNG.
        {
            let x = &self.x;
            let slots = &mut self.mt_slots[..m];
            let rngs = &mut self.worker_rngs[..m];
            let bank = ctx.oracles;
            let codecs = ctx.codecs;
            let fb: &(dyn FeedbackMemory) = &*ctx.feedback;
            let drop_prob = ctx.drop_prob;
            let per = m.div_ceil(threads.clamp(1, m));
            if per >= m {
                for (i, (slot, wrng)) in slots.iter_mut().zip(rngs.iter_mut()).enumerate() {
                    mt_worker_phase(fb, bank, codecs, drop_prob, i, x, step, slot, wrng);
                }
            } else {
                std::thread::scope(|s| {
                    for (c, (slot_chunk, rng_chunk)) in
                        slots.chunks_mut(per).zip(rngs.chunks_mut(per)).enumerate()
                    {
                        let base = c * per;
                        s.spawn(move || {
                            for (k, (slot, wrng)) in
                                slot_chunk.iter_mut().zip(rng_chunk.iter_mut()).enumerate()
                            {
                                mt_worker_phase(
                                    fb,
                                    bank,
                                    codecs,
                                    drop_prob,
                                    base + k,
                                    x,
                                    step,
                                    slot,
                                    wrng,
                                );
                            }
                        });
                    }
                });
            }
        }

        // Server phase, sequential in worker-id order: bit accounting,
        // feedback post_decode, consensus accumulation — float-for-float
        // the inline loop.
        let p = m.max(1);
        self.consensus.fill(0.0);
        let mut round_bits = 0usize;
        let mut delivered = 0usize;
        {
            let slots = &self.mt_slots[..m];
            let consensus = &mut self.consensus;
            let trace = &mut self.trace;
            for (i, slot) in slots.iter().enumerate() {
                if slot.encoded {
                    round_bits += slot.msg.payload_bits;
                    trace.total_payload_bits += slot.msg.payload_bits;
                    trace.total_side_bits += slot.msg.side_bits;
                }
                if slot.arrived {
                    let estimate: &[f32] = if slot.encoded { &slot.q } else { &slot.g };
                    ctx.feedback.post_decode(i, estimate, &slot.g);
                    delivered += 1;
                    for (ci, &ei) in consensus.iter_mut().zip(estimate) {
                        *ci += ei / p as f32;
                    }
                }
            }
        }
        self.close_round(ctx.problem, ctx.domain, ctx.x_star, step, round_bits, delivered);
        true
    }

    /// Build (or rebuild after a worker-count change) the per-worker
    /// threaded-executor slots, drawing the f32 buffers from the fleet's
    /// recycled pools. Dirty reuse is safe: `g`/`z`/`q` are fully
    /// overwritten before they are read each round.
    fn ensure_mt_slots(&mut self, m: usize, codecs: Codecs<'_>, pools: &Arc<ChannelPools>) {
        let n = self.x.len();
        if self.mt_slots.len() == m && self.mt_slots.iter().all(|s| s.g.len() == n) {
            return;
        }
        self.release_mt_slots(pools);
        let mut grab = || {
            let mut v = pools.iterates.get_or(|| Vec::with_capacity(n));
            v.clear();
            v.resize(n, 0.0);
            v
        };
        self.mt_slots = (0..m)
            .map(|i| WorkerSlot {
                g: grab(),
                z: grab(),
                q: grab(),
                idx: Vec::new(),
                ws: match codecs.get(i) {
                    Some(c) => Workspace::for_compressor(c),
                    None => Workspace::new(),
                },
                msg: Compressed::empty(n),
                encoded: false,
                arrived: false,
            })
            .collect();
    }

    /// Return the threaded-executor buffers to the fleet pools (job
    /// eviction / migration hands its warm buffers to the tenants that
    /// stay). Idempotent; a run that never stepped threaded has nothing
    /// to release.
    pub(crate) fn release_mt_slots(&mut self, pools: &Arc<ChannelPools>) {
        for mut slot in self.mt_slots.drain(..) {
            pools.iterates.put(std::mem::take(&mut slot.g));
            pools.iterates.put(std::mem::take(&mut slot.z));
            pools.iterates.put(std::mem::take(&mut slot.q));
            pools.bytes.put(std::mem::take(&mut slot.msg.bytes));
        }
    }

    /// Close the trace: push the trailing record (when the output mode
    /// carries one) and set `final_x`. Idempotent — finalizing twice is a
    /// no-op, and a finalized state refuses further [`RunState::step`]s.
    pub fn finalize(&mut self, problem: Problem<'_>, output: OutputMode, x_star: Option<&[f32]>) {
        if self.finalized {
            return;
        }
        if let OutputMode::LastIterate { trailing: true } = output {
            self.trace.records.push(IterRecord {
                value: problem.value(&self.x),
                dist_to_opt: x_star.map(|xs| dist2(&self.x, xs)).unwrap_or(f32::NAN),
                payload_bits: 0,
                participants: 0,
            });
        }
        self.trace.final_x = if self.averaging { self.avg.clone() } else { self.x.clone() };
        self.finalized = true;
    }
}

/// One worker's share of a threaded round: the same shift → query →
/// pre-encode → encode → drop-verdict sequence as the inline loop, with
/// every mutable touched value confined to the worker's own slot and
/// forked RNG stream. The decode also runs here — it is deterministic
/// (no RNG), so moving it off the server phase changes wall-clock, not
/// results.
#[allow(clippy::too_many_arguments)]
fn mt_worker_phase(
    fb: &dyn FeedbackMemory,
    bank: &dyn SharedOracleBank,
    codecs: Codecs<'_>,
    drop_prob: f32,
    i: usize,
    x: &[f32],
    step: f32,
    slot: &mut WorkerSlot,
    wrng: &mut Rng,
) {
    let shifted = fb.shift_point(i, x, step, &mut slot.z);
    let point: &[f32] = if shifted { &slot.z } else { x };
    bank.query_shared(i, point, wrng, &mut slot.idx, &mut slot.g);
    fb.pre_encode(i, &mut slot.g);
    let codec = codecs.get(i);
    slot.encoded = codec.is_some();
    if let Some(c) = codec {
        c.compress_into(&slot.g, wrng, &mut slot.ws, &mut slot.msg);
    }
    // Same verdict draw, same stream position as the inline path: bits
    // are charged on send, not delivery.
    slot.arrived = drop_prob <= 0.0 || wrng.uniform_f32() >= drop_prob;
    if slot.arrived {
        if let Some(c) = codec {
            c.decompress_into(&slot.msg, &mut slot.ws, &mut slot.q);
        }
    }
}

/// A suspended-and-resumable engine run: the spec plus its [`RunState`].
/// Produced by [`Engine::start`]; each [`EngineRun::step`] executes one
/// round, so callers (drivers, the serving layer's harnesses, tests) can
/// interleave rounds of many runs or park a run indefinitely.
pub struct EngineRun<'a> {
    spec: Engine<'a>,
    st: RunState,
    x_star: Option<Vec<f32>>,
}

impl<'a> EngineRun<'a> {
    /// Execute the next round. Returns `false` (consuming no randomness)
    /// once all configured rounds have run. The spec's probe fires after
    /// each executed round, exactly as under [`Engine::run`].
    pub fn step(&mut self, rng: &mut Rng) -> bool {
        {
            let mut ctx = RoundCtx {
                problem: self.spec.problem,
                oracles: &mut self.spec.oracles,
                codecs: self.spec.codecs,
                schedule: self.spec.schedule.as_ref(),
                feedback: self.spec.feedback.as_mut(),
                domain: self.spec.domain,
                participation: self.spec.participation,
                drop_prob: self.spec.drop_prob,
                rng_policy: self.spec.rng_policy,
                rounds: self.spec.rounds,
                x_star: self.x_star.as_deref(),
            };
            if !self.st.step(&mut ctx, rng) {
                return false;
            }
        }
        if let Some(probe) = self.spec.probe.as_mut() {
            probe(self.st.t - 1);
        }
        true
    }

    /// Rounds executed so far.
    pub fn round(&self) -> usize {
        self.st.round()
    }

    /// Whether every configured round has executed.
    pub fn is_done(&self) -> bool {
        self.st.t >= self.spec.rounds
    }

    /// The trace accumulated so far.
    pub fn trace(&self) -> &Trace {
        self.st.trace()
    }

    /// The current iterate.
    pub fn iterate(&self) -> &[f32] {
        self.st.iterate()
    }

    /// Finalize and return the trace (trailing record + `final_x`), as
    /// [`Engine::run`] would have.
    pub fn finish(mut self) -> Trace {
        self.st.finalize(self.spec.problem, self.spec.output, self.x_star.as_deref());
        std::mem::take(&mut self.st.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::oracle::ExactGrad;
    use super::schedule::Schedule;
    use super::*;
    use crate::linalg::vecops::matvec;
    use crate::opt::objectives::Loss;
    use crate::quant::ndsc::Ndsc;

    fn planted_lsq(m: usize, n: usize, seed: u64) -> (DatasetObjective, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; m];
        matvec(&a, m, n, &xs, &mut b);
        (DatasetObjective::new(a, b, m, n, Loss::Square, 0.0), xs)
    }

    #[test]
    fn unquantized_spec_converges_like_gd() {
        let (obj, xs) = planted_lsq(60, 10, 1);
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(2);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::Constant(schedule::optimal_sc_step(l, mu)),
            121,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_output(OutputMode::LastIterate { trailing: false })
        .run(&vec![0.0; 10], Some(&xs), &mut rng);
        assert_eq!(tr.records.len(), 121);
        assert!(tr.records.last().unwrap().dist_to_opt < 1e-2);
        assert_eq!(tr.total_payload_bits, 0);
        assert!(tr.records.iter().all(|r| r.participants <= 1));
    }

    #[test]
    fn quantized_feedback_spec_converges() {
        // The DGD-DEF composition, built directly on the engine API.
        let (obj, _) = planted_lsq(80, 16, 3);
        let xs = obj.quadratic_minimizer();
        let (l, mu) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(4);
        let c = Ndsc::hadamard(16, 6.0, &mut rng);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::Constant(schedule::optimal_sc_step(l, mu)),
            150,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_codecs(Codecs::Shared(&c))
        .with_feedback(feedback::DefFeedback::new(1, 16))
        .run(&vec![0.0; 16], Some(&xs), &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 1e-2 * d0, "no convergence: {d0} -> {dt}");
        assert_eq!(tr.records.len(), 151, "150 pre-step records + trailing");
        assert!(tr.total_payload_bits > 0);
    }

    #[test]
    fn decaying_schedule_is_a_one_line_change() {
        // The composition the engine unlocks: DGD-DEF machinery with an
        // O(1/√t) schedule — no new loop file required.
        let (obj, xs) = planted_lsq(60, 8, 5);
        let (l, _) = obj.smoothness_strong_convexity();
        let mut rng = Rng::seed_from(6);
        let tr = Engine::new(
            Problem::Single(&obj),
            Schedule::InvSqrt { c: 1.0 / l },
            200,
        )
        .with_oracle(ExactGrad { obj: &obj })
        .with_output(OutputMode::LastIterate { trailing: false })
        .run(&vec![0.0; 8], Some(&xs), &mut rng);
        let d0 = tr.records[0].dist_to_opt;
        let dt = tr.records.last().unwrap().dist_to_opt;
        assert!(dt < 0.5 * d0, "decaying-step run made no progress: {d0} -> {dt}");
    }

    #[test]
    fn lossy_uplink_applies_to_unquantized_specs_too() {
        // drop ≥ 1 is the all-drops degenerate case (legacy open-range
        // semantics): no upload ever lands, so no step is ever taken —
        // on the unquantized path as much as on the quantized one.
        let (obj, _) = planted_lsq(20, 6, 9);
        let mut rng = Rng::seed_from(10);
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(0.1), 8)
            .with_oracle(ExactGrad { obj: &obj })
            .with_drop_prob(1.0)
            .with_output(OutputMode::LastIterate { trailing: false })
            .run(&vec![0.5; 6], None, &mut rng);
        assert!(tr.records.iter().all(|r| r.participants == 0));
        assert_eq!(tr.final_x, vec![0.5; 6]);
        // A partially lossy unquantized link: some rounds must drop.
        let mut rng = Rng::seed_from(11);
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(1e-3), 40)
            .with_oracle(ExactGrad { obj: &obj })
            .with_drop_prob(0.5)
            .with_output(OutputMode::LastIterate { trailing: false })
            .run(&vec![0.5; 6], None, &mut rng);
        assert!(tr.records.iter().any(|r| r.participants == 0));
        assert!(tr.records.iter().any(|r| r.participants == 1));
    }

    #[test]
    fn stepped_run_is_bit_identical_to_run_to_completion() {
        // The re-entrancy contract: start + step-at-a-time + finish must
        // reproduce Engine::run exactly — including when the run is
        // parked between rounds (nothing here draws RNG while parked).
        let (obj, xs) = planted_lsq(80, 16, 13);
        let (l, mu) = obj.smoothness_strong_convexity();
        let c_a = Ndsc::hadamard_dithered(16, 3.0, &mut Rng::seed_from(14));
        let c_b = Ndsc::hadamard_dithered(16, 3.0, &mut Rng::seed_from(14));
        let mk = |c| {
            Engine::new(
                Problem::Single(&obj),
                Schedule::Constant(schedule::optimal_sc_step(l, mu)),
                40,
            )
            .with_oracle(ExactGrad { obj: &obj })
            .with_codecs(Codecs::Shared(c))
            .with_feedback(feedback::DefFeedback::new(1, 16))
        };
        let mut rng_a = Rng::seed_from(15);
        let whole = mk(&c_a).run(&vec![0.0; 16], Some(&xs), &mut rng_a);
        let mut rng_b = Rng::seed_from(15);
        let mut run = mk(&c_b).start(&vec![0.0; 16], Some(&xs), &mut rng_b);
        let mut steps = 0;
        while run.step(&mut rng_b) {
            steps += 1;
            assert_eq!(run.round(), steps);
        }
        assert!(run.is_done());
        assert_eq!(steps, 40);
        assert!(!run.step(&mut rng_b), "a done run must refuse further steps");
        let stepped = run.finish();
        assert_eq!(whole.records.len(), stepped.records.len());
        for (a, b) in whole.records.iter().zip(&stepped.records) {
            assert_eq!(a.value.to_bits(), b.value.to_bits());
            assert_eq!(a.dist_to_opt.to_bits(), b.dist_to_opt.to_bits());
            assert_eq!(a.payload_bits, b.payload_bits);
        }
        assert_eq!(
            whole.final_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            stepped.final_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(whole.total_payload_bits, stepped.total_payload_bits);
        assert_eq!(whole.total_side_bits, stepped.total_side_bits);
    }

    #[test]
    fn threaded_step_mt_is_bit_identical_to_inline_step() {
        // The serving composition (sharded problem, per-worker dithered
        // codecs, DEF feedback, lossy uplink, forked RNGs) stepped three
        // ways: inline, step_mt with one chunk, step_mt fanned out. All
        // three must agree bit-for-bit — trace, iterate, totals, and the
        // feedback memories left behind.
        let n = 16;
        let m = 3;
        let rounds = 17;
        let mut rng = Rng::seed_from(21);
        let shards: Vec<DatasetObjective> = (0..m)
            .map(|_| {
                let a: Vec<f32> = (0..10 * n).map(|_| rng.gaussian_f32()).collect();
                let b: Vec<f32> = (0..10).map(|_| rng.gaussian_f32()).collect();
                DatasetObjective::new(a, b, 10, n, Loss::Square, 0.0)
            })
            .collect();
        let problem = ShardedProblem::new(shards);

        struct Bank<'a> {
            shards: &'a [DatasetObjective],
            batch: usize,
            idx: Vec<usize>,
        }
        impl OracleBank for Bank<'_> {
            fn workers(&self) -> usize {
                self.shards.len()
            }
            fn query(&mut self, i: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
                let obj = &self.shards[i];
                rng.sample_indices_into(obj.m, self.batch.min(obj.m), &mut self.idx);
                obj.minibatch_gradient(x, Some(&self.idx), out);
            }
        }
        impl SharedOracleBank for Bank<'_> {
            fn query_shared(
                &self,
                i: usize,
                x: &[f32],
                rng: &mut Rng,
                idx: &mut Vec<usize>,
                out: &mut [f32],
            ) {
                let obj = &self.shards[i];
                rng.sample_indices_into(obj.m, self.batch.min(obj.m), idx);
                obj.minibatch_gradient(x, Some(idx), out);
            }
        }

        let codecs: Vec<Box<dyn Compressor>> = (0..m)
            .map(|i| {
                Box::new(Ndsc::hadamard_dithered(n, 2.0, &mut Rng::seed_from(30 + i as u64)))
                    as Box<dyn Compressor>
            })
            .collect();
        let sched = Schedule::Constant(0.05);
        let domain = Domain::L2Ball { radius: 8.0 };
        let drop_prob = 0.3;
        let x0 = vec![0.0f32; n];

        let run_inline = || {
            let mut bank = Bank { shards: &problem.shards, batch: 4, idx: Vec::new() };
            let mut fb = feedback::DefFeedback::new(m, n);
            let mut rng = Rng::seed_from(99);
            let mut st = RunState::new(
                &x0,
                m,
                rounds,
                domain,
                RngPolicy::ForkPerWorker,
                OutputMode::PolyakAverage,
                Some(codecs[0].as_ref()),
                &mut rng,
            );
            let mut ctx = RoundCtx {
                problem: Problem::Sharded(&problem),
                oracles: &mut bank,
                codecs: Codecs::PerWorker(&codecs),
                schedule: &sched,
                feedback: &mut fb,
                domain,
                participation: Participation::Full,
                drop_prob,
                rng_policy: RngPolicy::ForkPerWorker,
                rounds,
                x_star: None,
            };
            while st.step(&mut ctx, &mut rng) {}
            st.finalize(Problem::Sharded(&problem), OutputMode::PolyakAverage, None);
            let mut fb_state = Vec::new();
            fb.save_state(&mut fb_state);
            (std::mem::take(&mut st.trace), fb_state)
        };
        let run_mt = |threads: usize| {
            let bank = Bank { shards: &problem.shards, batch: 4, idx: Vec::new() };
            let mut fb = feedback::DefFeedback::new(m, n);
            let mut rng = Rng::seed_from(99);
            let mut st = RunState::new(
                &x0,
                m,
                rounds,
                domain,
                RngPolicy::ForkPerWorker,
                OutputMode::PolyakAverage,
                Some(codecs[0].as_ref()),
                &mut rng,
            );
            let pools = Arc::new(ChannelPools::new(m));
            let mut ctx = MtRoundCtx {
                problem: Problem::Sharded(&problem),
                oracles: &bank,
                codecs: Codecs::PerWorker(&codecs),
                schedule: &sched,
                feedback: &mut fb,
                domain,
                drop_prob,
                rounds,
                x_star: None,
            };
            while st.step_mt(&mut ctx, threads, &pools) {}
            st.release_mt_slots(&pools);
            st.finalize(Problem::Sharded(&problem), OutputMode::PolyakAverage, None);
            let mut fb_state = Vec::new();
            fb.save_state(&mut fb_state);
            (std::mem::take(&mut st.trace), fb_state)
        };

        let (tr_inline, fb_inline) = run_inline();
        for threads in [1usize, 2, m, m + 3] {
            let (tr_mt, fb_mt) = run_mt(threads);
            assert_eq!(tr_inline.records.len(), tr_mt.records.len(), "t={threads}");
            for (t, (a, b)) in tr_inline.records.iter().zip(&tr_mt.records).enumerate() {
                assert_eq!(a.value.to_bits(), b.value.to_bits(), "t={threads} record {t} value");
                assert_eq!(a.payload_bits, b.payload_bits, "t={threads} record {t} bits");
                assert_eq!(a.participants, b.participants, "t={threads} record {t} delivered");
            }
            assert_eq!(
                tr_inline.final_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                tr_mt.final_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "t={threads} final_x"
            );
            assert_eq!(tr_inline.total_payload_bits, tr_mt.total_payload_bits);
            assert_eq!(tr_inline.total_side_bits, tr_mt.total_side_bits);
            assert_eq!(
                fb_inline.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fb_mt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "t={threads} feedback memories"
            );
        }
    }

    #[test]
    fn probe_sees_every_round() {
        let (obj, _) = planted_lsq(20, 4, 7);
        let mut rng = Rng::seed_from(8);
        let mut seen = Vec::new();
        let tr = Engine::new(Problem::Single(&obj), Schedule::Constant(1e-3), 5)
            .with_oracle(ExactGrad { obj: &obj })
            .with_output(OutputMode::LastIterate { trailing: false })
            .with_probe(|t| seen.push(t))
            .run(&vec![0.0; 4], None, &mut rng);
        drop(tr);
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
