//! Step-size schedules — the paper's step rules, single-sourced.
//!
//! Before the engine, the `optimal(l, mu, iters)` / `theory(...)`
//! constructions lived as near-copies inside the per-loop option structs
//! (`GdOptions`, `DgdDefOptions`, `PsgdOptions`, `DqPsgdOptions`). They
//! now live here; the legacy option structs delegate to these functions,
//! so the constants of Thm. 2 / Thm. 3 have exactly one definition.

/// The optimal smooth/strongly-convex step `α* = 2/(L+μ)` (Thm. 2) —
/// the step at which unquantized GD contracts at `σ = (L−μ)/(L+μ)`.
pub fn optimal_sc_step(l: f32, mu: f32) -> f32 {
    2.0 / (l + mu)
}

/// The unquantized PSGD theory step `α = D/(B·√T)` for the `D·B/√T`
/// suboptimality guarantee (general convex, non-smooth).
pub fn psgd_theory_step(d: f32, b: f32, iters: usize) -> f32 {
    d / (b * (iters as f32).sqrt())
}

/// Theorem 3's DQ-PSGD step `α = D/(B·K_u)·√(min{R,1}/T)` — optimal for
/// every bit budget `R ∈ (0, ∞)`, sub-linear budgets included.
pub fn dq_psgd_theory_step(d: f32, b: f32, r: f32, ku: f32, iters: usize) -> f32 {
    d / (b * ku) * (r.min(1.0) / iters as f32).sqrt()
}

/// A per-round step-size rule `t ↦ α_t`.
///
/// The engine queries the schedule once per round, so adaptive-precision
/// and decaying-step runs are one-line compositions instead of new loop
/// files. All six legacy algorithms use [`Schedule::Constant`] (their
/// theory steps are horizon-dependent constants, computed by the
/// functions above).
pub trait StepSchedule {
    /// Step size for round `t` (0-based).
    fn step(&self, t: usize) -> f32;
}

/// The built-in schedule zoo.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Fixed `α` for the whole run.
    Constant(f32),
    /// Anytime `O(1/√T)` decay: `α_t = c/√(t+1)` (the horizon-free
    /// variant of Thm. 3's step).
    InvSqrt { c: f32 },
    /// Strongly-convex decay `α_t = c/(t₀ + t)`.
    Harmonic { c: f32, t0: f32 },
}

impl StepSchedule for Schedule {
    fn step(&self, t: usize) -> f32 {
        match *self {
            Schedule::Constant(s) => s,
            Schedule::InvSqrt { c } => c / ((t + 1) as f32).sqrt(),
            Schedule::Harmonic { c, t0 } => c / (t0 + t as f32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant(0.25);
        assert_eq!(s.step(0), 0.25);
        assert_eq!(s.step(1000), 0.25);
    }

    #[test]
    fn inv_sqrt_decays() {
        let s = Schedule::InvSqrt { c: 1.0 };
        assert_eq!(s.step(0), 1.0);
        assert!((s.step(3) - 0.5).abs() < 1e-6);
        assert!(s.step(100) < s.step(10));
    }

    #[test]
    fn harmonic_decays() {
        let s = Schedule::Harmonic { c: 2.0, t0: 1.0 };
        assert_eq!(s.step(0), 2.0);
        assert_eq!(s.step(1), 1.0);
    }

    #[test]
    fn theory_steps_match_legacy_formulas() {
        // The exact expressions the option structs used before the
        // dedup — changing these changes every experiment.
        let (l, mu) = (10.0f32, 2.0f32);
        assert_eq!(optimal_sc_step(l, mu), 2.0 / (l + mu));
        let (d, b, iters) = (4.0f32, 3.0f32, 400usize);
        assert_eq!(psgd_theory_step(d, b, iters), d / (b * (iters as f32).sqrt()));
        let (r, ku) = (0.5f32, 1.0f32);
        assert_eq!(
            dq_psgd_theory_step(d, b, r, ku, iters),
            d / (b * ku) * (r.min(1.0) / iters as f32).sqrt()
        );
    }
}
