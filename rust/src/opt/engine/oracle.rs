//! Oracle adapters — the worker-side gradient access of an engine round.
//!
//! The engine passes every oracle query the worker's *round randomness*
//! (the shared run RNG or the worker's forked stream, per
//! [`crate::opt::engine::RngPolicy`]); adapters either draw their batch
//! from it (the multi-worker convention, where batch draw and codec
//! dither come from one per-worker stream) or ignore it because they own
//! their noise source (the legacy [`crate::opt::oracle`] types).

use crate::linalg::rng::Rng;
use crate::opt::objectives::DatasetObjective;

/// Worker-side gradient access for one engine round.
pub trait Oracle {
    /// Problem dimension `n`.
    fn dim(&self) -> usize;
    /// Write a (sub)gradient estimate at `x` into `out`. `rng` is the
    /// worker's round randomness; oracles with their own noise source
    /// ignore it.
    fn query(&mut self, x: &[f32], rng: &mut Rng, out: &mut [f32]);
}

/// Exact full-gradient oracle over a (shard) objective — setting (i),
/// §4.1. Draws no randomness.
pub struct ExactGrad<'a> {
    pub obj: &'a DatasetObjective,
}

impl Oracle for ExactGrad<'_> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn query(&mut self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        self.obj.gradient(x, out);
    }
}

/// Sharded-minibatch oracle: worker `i`'s view of its private shard.
/// `batch = None` is the full local gradient; `Some(b)` samples `b` rows
/// from the worker's round RNG (so traces are independent of worker
/// scheduling, exactly as the legacy multi-worker loop drew them).
/// Queries are allocation-free: the index buffer is owned and reused.
pub struct ShardOracle<'a> {
    obj: &'a DatasetObjective,
    batch: Option<usize>,
    idx: Vec<usize>,
}

impl<'a> ShardOracle<'a> {
    pub fn new(obj: &'a DatasetObjective, batch: Option<usize>) -> Self {
        ShardOracle { obj, batch, idx: Vec::new() }
    }
}

impl Oracle for ShardOracle<'_> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn query(&mut self, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        match self.batch {
            Some(bsz) => {
                rng.sample_indices_into(self.obj.m, bsz.min(self.obj.m), &mut self.idx);
                self.obj.minibatch_gradient(x, Some(&self.idx), out);
            }
            None => self.obj.gradient(x, out),
        }
    }
}

/// Adapter over the legacy [`crate::opt::oracle::Oracle`] trait (which
/// owns its noise source, e.g. [`crate::opt::oracle::MinibatchOracle`]):
/// the engine's round RNG is ignored, so a run driven through this
/// adapter consumes exactly the RNG streams the legacy loops did.
pub struct OwnNoise<'a>(pub &'a mut dyn crate::opt::oracle::Oracle);

impl Oracle for OwnNoise<'_> {
    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn query(&mut self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        self.0.query(x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::objectives::Loss;

    fn lsq(m: usize, n: usize, seed: u64) -> DatasetObjective {
        let mut rng = Rng::seed_from(seed);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.gaussian_f32()).collect();
        DatasetObjective::new(a, b, m, n, Loss::Square, 0.0)
    }

    #[test]
    fn exact_grad_matches_objective() {
        let obj = lsq(20, 6, 1);
        let mut o = ExactGrad { obj: &obj };
        let x = vec![0.2f32; 6];
        let mut g1 = vec![0.0f32; 6];
        let mut g2 = vec![0.0f32; 6];
        let mut rng = Rng::seed_from(2);
        o.query(&x, &mut rng, &mut g1);
        obj.gradient(&x, &mut g2);
        assert_eq!(g1, g2);
        assert_eq!(o.dim(), 6);
    }

    #[test]
    fn shard_oracle_full_and_batched() {
        let obj = lsq(20, 6, 3);
        let x = vec![0.1f32; 6];
        // Full gradient: identical to the objective, no rng consumed.
        let mut full = ShardOracle::new(&obj, None);
        let mut rng = Rng::seed_from(4);
        let before = rng.next_u64();
        let mut rng = Rng::seed_from(4);
        let mut g = vec![0.0f32; 6];
        full.query(&x, &mut rng, &mut g);
        assert_eq!(rng.next_u64(), before, "full gradient must not draw");
        // Batched: draws the same indices as a bare sample_indices_into.
        let mut batched = ShardOracle::new(&obj, Some(5));
        let mut rng_a = Rng::seed_from(5);
        let mut gb = vec![0.0f32; 6];
        batched.query(&x, &mut rng_a, &mut gb);
        let mut rng_b = Rng::seed_from(5);
        let mut idx = Vec::new();
        rng_b.sample_indices_into(20, 5, &mut idx);
        let mut want = vec![0.0f32; 6];
        obj.minibatch_gradient(&x, Some(&idx), &mut want);
        assert_eq!(gb, want);
    }

    #[test]
    fn own_noise_wraps_legacy_oracle() {
        let obj = lsq(20, 6, 6);
        let mut inner = crate::opt::oracle::MinibatchOracle::new(&obj, 4, Rng::seed_from(7));
        let mut o = OwnNoise(&mut inner);
        let mut rng = Rng::seed_from(8);
        let mut g = vec![0.0f32; 6];
        o.query(&vec![0.0; 6], &mut rng, &mut g);
        assert!(g.iter().all(|v| v.is_finite()));
    }
}
