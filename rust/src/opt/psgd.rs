//! Projected stochastic subgradient descent — the unquantized reference for
//! the general convex non-smooth setting (§4.2), with Polyak–Ruppert
//! averaging (`x_T = (1/T)Σ x̂_t`, the output of Alg. 2 with `Q = id`).
//!
//! Engine spec: `OwnNoise` adapter over the caller's oracle, constant
//! step, no codec, no feedback, Polyak-average output.

use crate::linalg::rng::Rng;
use crate::opt::engine::oracle::OwnNoise;
use crate::opt::engine::schedule::{psgd_theory_step, Schedule};
use crate::opt::engine::{Engine, OutputMode, Problem};
use crate::opt::objectives::DatasetObjective;
use crate::opt::oracle::Oracle;
use crate::opt::projection::Domain;
use crate::opt::Trace;

#[derive(Clone, Copy, Debug)]
pub struct PsgdOptions {
    pub step: f32,
    pub iters: usize,
    pub domain: Domain,
}

impl PsgdOptions {
    /// The theory step for suboptimality `DB/√T`: `α = D/(B√T)` —
    /// single-sourced in [`crate::opt::engine::schedule`].
    pub fn theory(d: f32, b: f32, iters: usize, domain: Domain) -> Self {
        PsgdOptions { step: psgd_theory_step(d, b, iters), iters, domain }
    }
}

/// Run projected SGD; records the objective value of the **running
/// average** (the algorithm's output), as plotted in Fig. 2.
pub fn run(
    obj: &DatasetObjective,
    oracle: &mut dyn Oracle,
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: PsgdOptions,
    rng: &mut Rng,
) -> Trace {
    Engine::new(Problem::Single(obj), Schedule::Constant(opts.step), opts.iters)
        .with_oracle(OwnNoise(oracle))
        .with_domain(opts.domain)
        .with_output(OutputMode::PolyakAverage)
        .run(x0, x_star, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::objectives::Loss;
    use crate::opt::oracle::MinibatchOracle;

    #[test]
    fn psgd_reduces_hinge_loss() {
        let mut rng = Rng::seed_from(1);
        let (m, n) = (100, 30);
        // Two-Gaussian classes as in Fig. 2a.
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m];
        for i in 0..m {
            let cls = if i % 2 == 0 { 1.0 } else { -1.0 };
            for j in 0..n {
                a[i * n + j] = rng.gaussian_f32() + cls * 0.8;
            }
            b[i] = cls;
        }
        let obj = DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0);
        let mut oracle = MinibatchOracle::new(&obj, 10, Rng::seed_from(2));
        let opts = PsgdOptions {
            step: 0.05,
            iters: 400,
            domain: Domain::L2Ball { radius: 10.0 },
        };
        let trace = run(&obj, &mut oracle, &vec![0.0; n], None, opts, &mut rng);
        let first = trace.records[5].value;
        let last = trace.final_value();
        assert!(last < 0.7 * first, "no progress: {first} -> {last}");
        assert!(obj.classification_error(&trace.final_x) < 0.2);
    }

    #[test]
    fn iterates_stay_in_domain() {
        let mut rng = Rng::seed_from(3);
        let (m, n) = (20, 5);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_cubed()).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.sign()).collect();
        let obj = DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0);
        let mut oracle = MinibatchOracle::new(&obj, 5, Rng::seed_from(4));
        let dom = Domain::L2Ball { radius: 0.5 };
        let opts = PsgdOptions { step: 0.3, iters: 50, domain: dom };
        let trace = run(&obj, &mut oracle, &vec![0.0; n], None, opts, &mut rng);
        assert!(dom.contains(&trace.final_x));
    }
}
