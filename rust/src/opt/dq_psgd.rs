//! **DQ-PSGD** — Democratically Quantized Projected Stochastic subGradient
//! Descent (Algorithm 2).
//!
//! Each iteration the worker draws a noisy subgradient, encodes it with the
//! **dithered** (unbiased) democratic source code `(E_Dith, D_Dith)` of
//! App. E, and the server takes a projected step on the decoded estimate;
//! the output is the running average. Theorem 3: with
//! `α = D/(B·K_u)·√(min{R,1}/T)` the expected suboptimality gap is
//! `K_u·D·B/√(T·min{1,R})` — minimax-optimal for every `R ∈ (0, ∞)`,
//! sub-linear budgets included, with **no error feedback needed** (the
//! dither's unbiasedness substitutes for it).
//!
//! Engine spec: `OwnNoise` oracle adapter, constant step, shared dithered
//! codec, no feedback, lossy uplink (`drop_prob`), Polyak-average output.

use crate::linalg::rng::Rng;
use crate::opt::engine::oracle::OwnNoise;
use crate::opt::engine::schedule::{dq_psgd_theory_step, Schedule};
use crate::opt::engine::{Codecs, Engine, OutputMode, Problem};
use crate::opt::objectives::DatasetObjective;
use crate::opt::oracle::Oracle;
use crate::opt::projection::Domain;
use crate::opt::Trace;
use crate::quant::Compressor;

#[derive(Clone, Copy, Debug)]
pub struct DqPsgdOptions {
    pub step: f32,
    pub iters: usize,
    pub domain: Domain,
    /// Lossy-uplink model (the `m = 1` case of the coordinator's SimNet
    /// links): each round's codeword is lost independently with this
    /// probability — the bits are still spent, but the server takes no
    /// step that round. `0.0` = reliable link, and draws no randomness,
    /// so legacy traces are unchanged.
    pub drop_prob: f32,
}

impl DqPsgdOptions {
    /// Theorem 3's step size `α = D/(B·K_u)·√(min{R,1}/T)` (single-sourced
    /// in [`crate::opt::engine::schedule`]); we take the empirical
    /// `K_u ≈ 1` for NDSC at λ = 1 (App. N).
    pub fn theory(d: f32, b: f32, r: f32, ku: f32, iters: usize, domain: Domain) -> Self {
        let step = dq_psgd_theory_step(d, b, r, ku, iters);
        DqPsgdOptions { step, iters, domain, drop_prob: 0.0 }
    }
}

/// Run Algorithm 2. `compressor` should be a dithered/unbiased scheme
/// (`compressor.is_unbiased()`), e.g. [`crate::quant::dsc::dsc_dithered`].
pub fn run(
    obj: &DatasetObjective,
    oracle: &mut dyn Oracle,
    compressor: &dyn Compressor,
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: DqPsgdOptions,
    rng: &mut Rng,
) -> Trace {
    Engine::new(Problem::Single(obj), Schedule::Constant(opts.step), opts.iters)
        .with_oracle(OwnNoise(oracle))
        .with_codecs(Codecs::Shared(compressor))
        .with_domain(opts.domain)
        .with_drop_prob(opts.drop_prob)
        .with_output(OutputMode::PolyakAverage)
        .run(x0, x_star, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::objectives::Loss;
    use crate::opt::oracle::MinibatchOracle;
    use crate::quant::gain_shape::StandardDither;
    use crate::quant::ndsc::Ndsc;

    fn two_gaussian_svm(m: usize, n: usize, seed: u64) -> DatasetObjective {
        let mut rng = Rng::seed_from(seed);
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m];
        for i in 0..m {
            let cls = if i % 2 == 0 { 1.0 } else { -1.0 };
            for j in 0..n {
                a[i * n + j] = rng.gaussian_f32() + cls * 0.8;
            }
            b[i] = cls;
        }
        DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0)
    }

    #[test]
    fn sublinear_budget_still_converges() {
        // The headline DQ-PSGD claim: R = 0.5 bits/dim suffices.
        let obj = two_gaussian_svm(100, 30, 1);
        let mut rng = Rng::seed_from(2);
        let c = Ndsc::hadamard_dithered(30, 0.5, &mut rng);
        let mut oracle = MinibatchOracle::new(&obj, 10, Rng::seed_from(3));
        let opts = DqPsgdOptions {
            step: 0.05,
            iters: 600,
            domain: Domain::L2Ball { radius: 10.0 },
            drop_prob: 0.0,
        };
        let trace = run(&obj, &mut oracle, &c, &vec![0.0; 30], None, opts, &mut rng);
        let early = trace.records[10].value;
        let late = trace.final_value();
        assert!(late < 0.8 * early, "no progress at R=0.5: {early} -> {late}");
        // payload exactly floor(30*0.5) = 15 bits for every non-zero
        // subgradient (zero subgradients send an empty payload).
        assert!(trace.records.iter().all(|r| r.payload_bits == 0 || r.payload_bits == 15));
        assert!(trace.records.iter().any(|r| r.payload_bits == 15));
    }

    #[test]
    fn lossy_uplink_still_makes_progress() {
        // 30% frame loss: slower, but the unbiased dithered steps that do
        // land must still drive the objective down; the bits are spent on
        // every round (sent-then-lost frames are charged).
        let obj = two_gaussian_svm(100, 30, 8);
        let mut rng = Rng::seed_from(9);
        let c = Ndsc::hadamard_dithered(30, 1.0, &mut rng);
        let mut oracle = MinibatchOracle::new(&obj, 10, Rng::seed_from(10));
        let opts = DqPsgdOptions {
            step: 0.05,
            iters: 800,
            domain: Domain::L2Ball { radius: 10.0 },
            drop_prob: 0.3,
        };
        let trace = run(&obj, &mut oracle, &c, &vec![0.0; 30], None, opts, &mut rng);
        let early = trace.records[10].value;
        let late = trace.final_value();
        assert!(late < 0.9 * early, "no progress at 30% loss: {early} -> {late}");
        assert_eq!(trace.records.len(), 800);
        // Payload accounting is per *send*, not per delivery.
        assert!(trace.records.iter().filter(|r| r.payload_bits > 0).count() > 700);
    }

    fn heavy_tailed_svm(m: usize, n: usize, seed: u64) -> DatasetObjective {
        // Heavy-tailed per-coordinate feature scales: the regime where the
        // embedding's flattening matters (paper's Gaussian³ inputs).
        let mut rng = Rng::seed_from(seed);
        let scales: Vec<f32> = (0..n).map(|_| 1.0 + rng.gaussian_cubed().abs()).collect();
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m];
        for i in 0..m {
            let cls = if i % 2 == 0 { 1.0 } else { -1.0 };
            for j in 0..n {
                a[i * n + j] = scales[j] * (rng.gaussian_f32() + cls * 0.8);
            }
            b[i] = cls;
        }
        DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0)
    }

    #[test]
    fn ndsc_beats_plain_dither_at_equal_budget() {
        // Fig. 2a's comparison, in expectation over a few seeds.
        let obj = heavy_tailed_svm(100, 30, 4);
        let mut wins = 0;
        for seed in 0..5u64 {
            let mut rng = Rng::seed_from(100 + seed);
            let ndsc = Ndsc::hadamard_dithered(30, 0.5, &mut rng);
            let plain = StandardDither::new(30, 0.5);
            let opts = DqPsgdOptions {
                step: 0.05,
                iters: 400,
                domain: Domain::L2Ball { radius: 10.0 },
                drop_prob: 0.0,
            };
            let mut o1 = MinibatchOracle::new(&obj, 10, Rng::seed_from(200 + seed));
            let t1 = run(&obj, &mut o1, &ndsc, &vec![0.0; 30], None, opts, &mut rng);
            let mut o2 = MinibatchOracle::new(&obj, 10, Rng::seed_from(200 + seed));
            let t2 = run(&obj, &mut o2, &plain, &vec![0.0; 30], None, opts, &mut rng);
            if t1.final_value() <= t2.final_value() {
                wins += 1;
            }
        }
        assert!(wins >= 3, "NDSC won only {wins}/5 runs");
    }

    #[test]
    fn output_in_domain_and_budget_respected() {
        let obj = two_gaussian_svm(60, 16, 5);
        let mut rng = Rng::seed_from(6);
        let c = Ndsc::hadamard_dithered(16, 2.0, &mut rng);
        let mut oracle = MinibatchOracle::new(&obj, 8, Rng::seed_from(7));
        let dom = Domain::L2Ball { radius: 2.0 };
        let opts = DqPsgdOptions { step: 0.1, iters: 100, domain: dom, drop_prob: 0.0 };
        let trace = run(&obj, &mut oracle, &c, &vec![0.0; 16], None, opts, &mut rng);
        assert!(dom.contains(&trace.final_x));
        // Zero subgradients (fully separated batches) legitimately send an
        // empty payload; every non-empty payload must spend exactly the
        // budget and never exceed it.
        let budget = crate::quant::budget_bits(16, 2.0);
        assert!(trace.records.iter().all(|r| r.payload_bits == 0 || r.payload_bits == budget));
        assert!(trace.records.iter().any(|r| r.payload_bits == budget));
    }
}
