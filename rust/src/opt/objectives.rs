//! The objective zoo of the paper's evaluation.
//!
//! One struct, [`DatasetObjective`], covers every experiment: a data matrix
//! `A ∈ R^{m×n}`, targets `b ∈ R^m`, a [`Loss`] and an optional `l₂`
//! regularizer. Square loss gives the smooth strongly-convex setting of
//! §4.1 (with computable `L`, `μ` and minimizer); hinge gives the general
//! convex non-smooth SVM of §5; logistic is included for completeness.

use crate::linalg::frames::{cholesky, cholesky_solve};
use crate::linalg::vecops::{dot, matvec, matvec_t, norm2};

/// Per-sample loss.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// `½(aᵀx − b)²` — least squares (Figs. 1b, 1d, 3a, 5, 6).
    Square,
    /// `max(0, 1 − b·aᵀx)` — SVM hinge (Fig. 2); `b ∈ {±1}`.
    Hinge,
    /// `log(1 + exp(−b·aᵀx))` — logistic; `b ∈ {±1}`.
    Logistic,
}

/// `f(x) = (1/m)·Σᵢ loss(aᵢᵀx, bᵢ) + (reg/2)‖x‖²`.
#[derive(Clone)]
pub struct DatasetObjective {
    /// Row-major `m × n` data matrix.
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub m: usize,
    pub n: usize,
    pub loss: Loss,
    /// `l₂` regularization coefficient (the "ridge" of Fig. 1d).
    pub reg: f32,
    /// Scale: `1/m` averaging (matches the paper's formulations).
    avg: f32,
}

impl DatasetObjective {
    pub fn new(a: Vec<f32>, b: Vec<f32>, m: usize, n: usize, loss: Loss, reg: f32) -> Self {
        assert_eq!(a.len(), m * n);
        assert_eq!(b.len(), m);
        DatasetObjective { a, b, m, n, loss, reg, avg: 1.0 / m as f32 }
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.a[i * self.n..(i + 1) * self.n]
    }

    /// Objective value.
    pub fn value(&self, x: &[f32]) -> f32 {
        let mut acc = 0.0f64;
        for i in 0..self.m {
            let z = dot(self.row(i), x);
            acc += match self.loss {
                Loss::Square => {
                    let d = (z - self.b[i]) as f64;
                    0.5 * d * d
                }
                Loss::Hinge => (1.0 - (self.b[i] * z) as f64).max(0.0),
                Loss::Logistic =>

                {
                    let t = (-(self.b[i] * z)) as f64;
                    // log(1+e^t) computed stably
                    if t > 30.0 {
                        t
                    } else {
                        t.exp().ln_1p()
                    }
                }
            };
        }
        (acc * self.avg as f64) as f32 + 0.5 * self.reg * norm2(x).powi(2)
    }

    /// Full (sub)gradient into `out`.
    pub fn gradient(&self, x: &[f32], out: &mut [f32]) {
        self.minibatch_gradient(x, None, out);
    }

    /// (Sub)gradient over a minibatch of row indices (`None` = all rows).
    /// Minibatch gradients are scaled by `1/|batch|`, making them unbiased
    /// estimates of the full gradient — the stochastic oracle of §5.
    pub fn minibatch_gradient(&self, x: &[f32], batch: Option<&[usize]>, out: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        // Two monomorphic loops instead of one boxed iterator: the gradient
        // is the worker hot path and must not heap-allocate per call.
        let count = match batch {
            Some(idx) => {
                for &i in idx {
                    self.accumulate_row_grad(x, out, i);
                }
                idx.len()
            }
            None => {
                for i in 0..self.m {
                    self.accumulate_row_grad(x, out, i);
                }
                self.m
            }
        };
        let scale = 1.0 / count.max(1) as f32;
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = *o * scale + self.reg * xi;
        }
    }

    /// Accumulate sample `i`'s (sub)gradient contribution into `out`.
    #[inline]
    fn accumulate_row_grad(&self, x: &[f32], out: &mut [f32], i: usize) {
        let row = self.row(i);
        let z = dot(row, x);
        let coef = match self.loss {
            Loss::Square => z - self.b[i],
            Loss::Hinge => {
                if self.b[i] * z < 1.0 {
                    -self.b[i]
                } else {
                    0.0
                }
            }
            Loss::Logistic => {
                let t = (self.b[i] * z) as f64;
                (-(self.b[i] as f64) / (1.0 + t.exp())) as f32
            }
        };
        if coef != 0.0 {
            for (o, &r) in out.iter_mut().zip(row) {
                *o += coef * r;
            }
        }
    }

    /// Hessian of the square-loss objective: `(1/m)AᵀA + reg·I` (row-major
    /// `n×n`). Panics for non-quadratic losses.
    pub fn quadratic_hessian(&self) -> Vec<f32> {
        assert_eq!(self.loss, Loss::Square, "hessian only for square loss");
        let mut h = vec![0.0f32; self.n * self.n];
        for i in 0..self.m {
            let row = self.row(i);
            for p in 0..self.n {
                if row[p] == 0.0 {
                    continue;
                }
                let rp = row[p] * self.avg;
                for q in 0..self.n {
                    h[p * self.n + q] += rp * row[q];
                }
            }
        }
        for p in 0..self.n {
            h[p * self.n + p] += self.reg;
        }
        h
    }

    /// `(L, μ)` of the square-loss objective via power iteration on the
    /// Hessian (λ_max) and on `L·I − H` (λ_min).
    pub fn smoothness_strong_convexity(&self) -> (f32, f32) {
        let h = self.quadratic_hessian();
        let l = lambda_max(&h, self.n);
        // λ_min(H) = l - λ_max(l·I - H)
        let mut shifted = h;
        for p in 0..self.n {
            for q in 0..self.n {
                let v = shifted[p * self.n + q];
                shifted[p * self.n + q] = if p == q { l - v } else { -v };
            }
        }
        let mu = (l - lambda_max(&shifted, self.n)).max(0.0);
        (l, mu)
    }

    /// Exact minimizer of the square-loss objective via the normal
    /// equations `(AᵀA/m + reg·I)x = Aᵀb/m` (Cholesky).
    pub fn quadratic_minimizer(&self) -> Vec<f32> {
        assert_eq!(self.loss, Loss::Square);
        let h = self.quadratic_hessian();
        let mut rhs = vec![0.0f32; self.n];
        matvec_t(&self.a, self.m, self.n, &self.b, &mut rhs);
        for v in rhs.iter_mut() {
            *v *= self.avg;
        }
        let l = cholesky(&h, self.n).expect("normal equations should be PD (add reg if rank-deficient)");
        cholesky_solve(&l, self.n, &mut rhs);
        rhs
    }

    /// Residual vector `Ax − b` (handy for tests).
    pub fn residual(&self, x: &[f32]) -> Vec<f32> {
        let mut r = vec![0.0f32; self.m];
        matvec(&self.a, self.m, self.n, x, &mut r);
        for (ri, &bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        r
    }

    /// Training classification error (fraction misclassified) for ±1
    /// labels — the y-axis of Fig. 2b/2d.
    pub fn classification_error(&self, x: &[f32]) -> f32 {
        let mut wrong = 0usize;
        for i in 0..self.m {
            let z = dot(self.row(i), x);
            if z * self.b[i] <= 0.0 {
                wrong += 1;
            }
        }
        wrong as f32 / self.m as f32
    }
}

/// Largest eigenvalue of a symmetric PSD matrix by power iteration.
pub fn lambda_max(h: &[f32], n: usize) -> f32 {
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    let mut hv = vec![0.0f32; n];
    let mut lambda = 0.0f32;
    for _ in 0..300 {
        matvec(h, n, n, &v, &mut hv);
        let nrm = norm2(&hv);
        if nrm == 0.0 {
            return 0.0;
        }
        let new_lambda = dot(&v, &hv);
        for (vi, &hvi) in v.iter_mut().zip(&hv) {
            *vi = hvi / nrm;
        }
        if (new_lambda - lambda).abs() < 1e-7 * new_lambda.abs().max(1.0) {
            return new_lambda;
        }
        lambda = new_lambda;
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::linalg::vecops::dist2;

    fn random_lsq(m: usize, n: usize, seed: u64) -> DatasetObjective {
        let mut rng = Rng::seed_from(seed);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let x_star: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; m];
        matvec(&a, m, n, &x_star, &mut b);
        DatasetObjective::new(a, b, m, n, Loss::Square, 0.0)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::seed_from(1);
        for loss in [Loss::Square, Loss::Logistic] {
            let (m, n) = (20, 6);
            let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
            let b: Vec<f32> = (0..m).map(|_| rng.sign()).collect();
            let obj = DatasetObjective::new(a, b, m, n, loss, 0.1);
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32() * 0.3).collect();
            let mut g = vec![0.0f32; n];
            obj.gradient(&x, &mut g);
            let eps = 1e-3;
            for j in 0..n {
                let mut xp = x.clone();
                xp[j] += eps;
                let mut xm = x.clone();
                xm[j] -= eps;
                let fd = (obj.value(&xp) - obj.value(&xm)) / (2.0 * eps);
                assert!(
                    (fd - g[j]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{loss:?} coord {j}: fd {fd} vs g {}",
                    g[j]
                );
            }
        }
    }

    #[test]
    fn hinge_subgradient_is_descentish() {
        // The hinge subgradient at non-kink points equals the FD derivative.
        let mut rng = Rng::seed_from(2);
        let (m, n) = (15, 4);
        let a: Vec<f32> = (0..m * n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.sign()).collect();
        let obj = DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0);
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut g = vec![0.0f32; n];
        obj.gradient(&x, &mut g);
        // Moving against the subgradient shouldn't increase the objective
        // (locally, for a small enough step on a convex function).
        let f0 = obj.value(&x);
        let step = 1e-4 / (1.0 + norm2(&g));
        let x2: Vec<f32> = x.iter().zip(&g).map(|(&xi, &gi)| xi - step * gi).collect();
        assert!(obj.value(&x2) <= f0 + 1e-6);
    }

    #[test]
    fn minimizer_zeroes_gradient_and_matches_planted() {
        let obj = random_lsq(40, 8, 3);
        let xs = obj.quadratic_minimizer();
        let mut g = vec![0.0f32; 8];
        obj.gradient(&xs, &mut g);
        assert!(norm2(&g) < 1e-3, "grad at minimizer: {}", norm2(&g));
        // Planted consistent system: minimum value ~ 0.
        assert!(obj.value(&xs) < 1e-5);
    }

    #[test]
    fn l_mu_bracket_hessian_quadratic_forms() {
        let mut rng = Rng::seed_from(4);
        let obj = random_lsq(30, 6, 5);
        let (l, mu) = obj.smoothness_strong_convexity();
        assert!(l > 0.0 && mu >= 0.0 && mu <= l);
        let h = obj.quadratic_hessian();
        for _ in 0..20 {
            let v: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
            let mut hv = vec![0.0f32; 6];
            matvec(&h, 6, 6, &v, &mut hv);
            let q = dot(&v, &hv) / dot(&v, &v);
            assert!(q <= l * 1.01 + 1e-5 && q >= mu * 0.99 - 1e-5, "q={q} not in [{mu},{l}]");
        }
    }

    #[test]
    fn minibatch_gradient_unbiased() {
        let mut rng = Rng::seed_from(6);
        let obj = random_lsq(50, 5, 7);
        let x: Vec<f32> = (0..5).map(|_| rng.gaussian_f32()).collect();
        let mut full = vec![0.0f32; 5];
        obj.gradient(&x, &mut full);
        let trials = 4000;
        let mut mean = vec![0.0f64; 5];
        let mut g = vec![0.0f32; 5];
        for _ in 0..trials {
            let batch = rng.sample_indices(50, 10);
            obj.minibatch_gradient(&x, Some(&batch), &mut g);
            for (m, &v) in mean.iter_mut().zip(&g) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &full) < 0.05 * (1.0 + norm2(&full)));
    }

    #[test]
    fn classification_error_perfect_vs_random() {
        // Separable data classified by its generator has zero error.
        let mut rng = Rng::seed_from(8);
        let (m, n) = (60, 5);
        let w: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m];
        for i in 0..m {
            for j in 0..n {
                a[i * n + j] = rng.gaussian_f32();
            }
            b[i] = if dot(&a[i * n..(i + 1) * n], &w) >= 0.0 { 1.0 } else { -1.0 };
        }
        let obj = DatasetObjective::new(a, b, m, n, Loss::Hinge, 0.0);
        assert_eq!(obj.classification_error(&w), 0.0);
        let junk: Vec<f32> = w.iter().map(|&v| -v).collect();
        assert_eq!(obj.classification_error(&junk), 1.0);
    }
}
