//! First-order optimization under a bit budget — §4 of the paper.
//!
//! * [`objectives`] — the objective zoo of the evaluation: least squares,
//!   ridge, hinge-loss SVM, logistic regression, with smoothness/strong
//!   convexity constants and closed-form minimizers where they exist.
//! * [`oracle`] — exact-gradient and stochastic-subgradient oracles.
//! * [`gd`] — unquantized gradient descent (the `σ = (L−μ)/(L+μ)` baseline).
//! * [`dgd_def`] — **DGD-DEF** (Alg. 1): quantized GD with democratically
//!   encoded error feedback; linear convergence at rate `max{ν, β}`.
//! * [`psgd`] / [`dq_psgd`] — projected stochastic subgradient descent and
//!   its democratically-quantized version **DQ-PSGD** (Alg. 2).
//! * [`multi`] — the multi-worker consensus loop (Alg. 3) in its
//!   single-process algorithmic form (the threaded runtime lives in
//!   [`crate::coordinator`]).
//! * [`projection`] — Euclidean-ball projection `Γ_X`.

pub mod dgd_def;
pub mod dq_psgd;
pub mod gd;
pub mod multi;
pub mod multi_def;
pub mod objectives;
pub mod oracle;
pub mod projection;
pub mod psgd;

/// Per-iteration record common to all optimizer traces.
#[derive(Clone, Debug, Default)]
pub struct IterRecord {
    /// Objective value at the current (or averaged) iterate.
    pub value: f32,
    /// `‖x_t − x*‖₂` when the minimizer is known, else `NaN`.
    pub dist_to_opt: f32,
    /// Quantized payload bits sent this iteration (0 for unquantized).
    pub payload_bits: usize,
}

/// Result of an optimizer run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<IterRecord>,
    pub final_x: Vec<f32>,
    /// Total payload bits across the run.
    pub total_payload_bits: usize,
    /// Total side-information bits.
    pub total_side_bits: usize,
}

impl Trace {
    /// Empirical linear rate `(‖x_T − x*‖ / ‖x_0 − x*‖)^{1/T}` — the y-axis
    /// of Fig. 1b. Clipped at 1 when diverging (as in the paper).
    pub fn empirical_rate(&self) -> f32 {
        if self.records.len() < 2 {
            return 1.0;
        }
        let d0 = self.records.first().unwrap().dist_to_opt;
        let dt = self.records.last().unwrap().dist_to_opt;
        if !(d0 > 0.0) || !dt.is_finite() {
            return 1.0;
        }
        let t = (self.records.len() - 1) as f32;
        ((dt / d0).powf(1.0 / t)).min(1.0)
    }

    pub fn final_value(&self) -> f32 {
        self.records.last().map(|r| r.value).unwrap_or(f32::NAN)
    }
}
