//! First-order optimization under a bit budget — §4 of the paper.
//!
//! All algorithms execute on **one round driver**, [`engine`]: oracle
//! call → (feedback-corrected) compress → wire → decode → consensus →
//! step, parameterized by pluggable oracles, step schedules, feedback
//! memories and drivers. The historical per-algorithm modules remain as
//! the stable entry points, each a thin spec-builder over the engine:
//!
//! * [`objectives`] — the objective zoo of the evaluation: least squares,
//!   ridge, hinge-loss SVM, logistic regression, with smoothness/strong
//!   convexity constants and closed-form minimizers where they exist.
//! * [`oracle`] — exact-gradient and stochastic-subgradient oracles
//!   (adapted into engine oracles by [`engine::oracle`]).
//! * [`gd`] — unquantized gradient descent (the `σ = (L−μ)/(L+μ)`
//!   baseline): `ExactGrad`, no codec, last-iterate output.
//! * [`dgd_def`] — **DGD-DEF** (Alg. 1): the `ExactGrad + DefFeedback`
//!   composition over a shared codec; linear convergence at `max{ν, β}`.
//! * [`psgd`] / [`dq_psgd`] — projected stochastic subgradient descent
//!   and its democratically-quantized version **DQ-PSGD** (Alg. 2):
//!   `OwnNoise + NoFeedback` with Polyak averaging, the latter over a
//!   dithered codec with an optional lossy uplink.
//! * [`multi`] / [`multi_def`] — the multi-worker consensus loops
//!   (Alg. 3 / §4.3): per-worker `ShardOracle`s or `ExactGrad`s, one
//!   codec per worker, k-of-m participation. The threaded runtime for
//!   the same specs is [`engine::driver::CoordinatorDriver`] /
//!   [`crate::coordinator`].
//! * [`projection`] — Euclidean-ball projection `Γ_X`.

pub mod dgd_def;
pub mod dq_psgd;
pub mod engine;
pub mod gd;
pub mod multi;
pub mod multi_def;
pub mod objectives;
pub mod oracle;
pub mod projection;
pub mod psgd;

/// Per-iteration record common to all optimizer traces.
#[derive(Clone, Debug, Default)]
pub struct IterRecord {
    /// Objective value at the current (or averaged) iterate.
    pub value: f32,
    /// `‖x_t − x*‖₂` when the minimizer is known, else `NaN`.
    pub dist_to_opt: f32,
    /// Quantized payload bits sent this iteration (0 for unquantized).
    pub payload_bits: usize,
    /// Workers whose uploads reached the consensus this round (0 on
    /// records that precede any step, e.g. trailing records).
    pub participants: usize,
}

/// Result of an optimizer run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<IterRecord>,
    pub final_x: Vec<f32>,
    /// Total payload bits across the run.
    pub total_payload_bits: usize,
    /// Total side-information bits.
    pub total_side_bits: usize,
}

impl Trace {
    /// Empirical linear rate `(‖x_T − x*‖ / ‖x_0 − x*‖)^{1/T}` — the y-axis
    /// of Fig. 1b. Clipped at 1 when diverging (as in the paper).
    pub fn empirical_rate(&self) -> f32 {
        if self.records.len() < 2 {
            return 1.0;
        }
        let d0 = self.records.first().unwrap().dist_to_opt;
        let dt = self.records.last().unwrap().dist_to_opt;
        if !(d0 > 0.0) || !dt.is_finite() {
            return 1.0;
        }
        let t = (self.records.len() - 1) as f32;
        ((dt / d0).powf(1.0 / t)).min(1.0)
    }

    pub fn final_value(&self) -> f32 {
        self.records.last().map(|r| r.value).unwrap_or(f32::NAN)
    }

    /// Per-round CSV in the shared schema of
    /// [`crate::coordinator::metrics`] (one writer for both runtimes).
    /// Inline runs have no worker-local losses or wall clock: those
    /// columns carry `NaN` / `0`. Cold path, so it simply goes through
    /// the [`Trace::to_run_metrics`] view.
    pub fn to_csv(&self) -> String {
        self.to_run_metrics().to_csv()
    }

    /// View this trace as coordinator-style [`RunMetrics`] so engine runs
    /// feed the same downstream consumers (rate summaries, CSV) as
    /// distributed runs.
    ///
    /// [`RunMetrics`]: crate::coordinator::metrics::RunMetrics
    pub fn to_run_metrics(&self) -> crate::coordinator::metrics::RunMetrics {
        use crate::coordinator::metrics::{RoundMetrics, RunMetrics};
        let mut m = RunMetrics {
            rounds: Vec::with_capacity(self.records.len()),
            total_payload_bits: self.total_payload_bits,
            total_overhead_bits: self.total_side_bits,
            rejected_messages: 0,
            final_iterate: self.final_x.clone(),
        };
        for (t, r) in self.records.iter().enumerate() {
            m.rounds.push(RoundMetrics {
                round: t as u64,
                value: r.value,
                mean_local_value: f32::NAN,
                payload_bits: r.payload_bits,
                participants: r.participants,
                wall: std::time::Duration::ZERO,
            });
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_trace() -> Trace {
        Trace {
            records: vec![
                IterRecord { value: 2.0, dist_to_opt: 1.0, payload_bits: 64, participants: 4 },
                IterRecord { value: 1.0, dist_to_opt: 0.5, payload_bits: 64, participants: 3 },
            ],
            final_x: vec![1.0, 2.0],
            total_payload_bits: 128,
            total_side_bits: 8,
        }
    }

    #[test]
    fn trace_csv_shares_the_coordinator_schema() {
        let t = demo_trace();
        let csv = t.to_csv();
        // One writer, one schema: the engine trace emits exactly the
        // coordinator header and row shape (participants included).
        assert!(csv.starts_with(crate::coordinator::metrics::CSV_HEADER));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("0,2,NaN,"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",64,4,0"));
    }

    #[test]
    fn run_metrics_roundtrip_preserves_totals() {
        let t = demo_trace();
        let m = t.to_run_metrics();
        assert_eq!(m.total_payload_bits, 128);
        assert_eq!(m.total_overhead_bits, 8);
        assert_eq!(m.final_iterate, vec![1.0, 2.0]);
        assert_eq!(m.rounds.len(), 2);
        assert!((m.mean_participants() - 3.5).abs() < 1e-6);
    }
}
