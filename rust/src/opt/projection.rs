//! Projection `Γ_X` onto the (compact convex) constraint set — the
//! projection step of Algorithms 2 and 3.

/// Constraint sets used in the experiments.
#[derive(Clone, Copy, Debug)]
pub enum Domain {
    /// All of `R^n` (no projection).
    Unconstrained,
    /// Euclidean ball `{‖x‖₂ ≤ radius}` centered at the origin; the
    /// paper's compact domain with diameter `D = 2·radius`.
    L2Ball { radius: f32 },
    /// Box `[lo, hi]^n`.
    Box { lo: f32, hi: f32 },
}

impl Domain {
    /// Project `x` in place.
    pub fn project(&self, x: &mut [f32]) {
        match *self {
            Domain::Unconstrained => {}
            Domain::L2Ball { radius } => {
                let nrm = crate::linalg::vecops::norm2(x);
                if nrm > radius {
                    let s = radius / nrm;
                    for v in x.iter_mut() {
                        *v *= s;
                    }
                }
            }
            Domain::Box { lo, hi } => {
                for v in x.iter_mut() {
                    *v = v.clamp(lo, hi);
                }
            }
        }
    }

    /// Diameter `D = sup ‖x − y‖₂` (infinite for unconstrained).
    pub fn diameter(&self, n: usize) -> f32 {
        match *self {
            Domain::Unconstrained => f32::INFINITY,
            Domain::L2Ball { radius } => 2.0 * radius,
            Domain::Box { lo, hi } => (hi - lo) * (n as f32).sqrt(),
        }
    }

    /// Whether `x` is inside (up to float slack).
    pub fn contains(&self, x: &[f32]) -> bool {
        match *self {
            Domain::Unconstrained => true,
            Domain::L2Ball { radius } => crate::linalg::vecops::norm2(x) <= radius * (1.0 + 1e-5),
            Domain::Box { lo, hi } => x.iter().all(|&v| v >= lo - 1e-6 && v <= hi + 1e-6),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::testkit::prop::{forall, Cases};

    #[test]
    fn ball_projection_is_idempotent_and_nonexpansive() {
        forall(Cases::new("ball projection", 100), |rng: &mut Rng, _| {
            let n = 1 + rng.below(50);
            let dom = Domain::L2Ball { radius: 1.0 + rng.uniform_f32() * 4.0 };
            let mut x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let before = dist2(&x, &y);
            dom.project(&mut x);
            dom.project(&mut y);
            assert!(dom.contains(&x));
            // idempotence
            let x1 = x.clone();
            dom.project(&mut x);
            assert!(dist2(&x, &x1) < 1e-6);
            // non-expansiveness
            assert!(dist2(&x, &y) <= before + 1e-5);
        });
    }

    #[test]
    fn interior_points_unchanged() {
        let dom = Domain::L2Ball { radius: 10.0 };
        let mut x = vec![1.0f32, 2.0, -1.5];
        let orig = x.clone();
        dom.project(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn boundary_scaling() {
        let dom = Domain::L2Ball { radius: 1.0 };
        let mut x = vec![3.0f32, 4.0];
        dom.project(&mut x);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        assert!((x[0] / x[1] - 0.75).abs() < 1e-6); // direction preserved
    }

    #[test]
    fn box_projection() {
        let dom = Domain::Box { lo: -1.0, hi: 1.0 };
        let mut x = vec![-3.0f32, 0.5, 7.0];
        dom.project(&mut x);
        assert_eq!(x, vec![-1.0, 0.5, 1.0]);
        assert!((dom.diameter(4) - 4.0).abs() < 1e-6);
    }
}
