//! Dense-vector helpers: norms, BLAS-1 style kernels and small dense
//! matrix–vector products used by frames, objectives and optimizers.

/// Euclidean norm `‖x‖₂` (f64 accumulation for stability).
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

/// Squared Euclidean norm `‖x‖₂²`.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() as f32
}

/// Max norm `‖x‖∞`.
#[inline]
pub fn norm_inf(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// `l1` norm `‖x‖₁`.
#[inline]
pub fn norm1(x: &[f32]) -> f32 {
    x.iter().map(|&v| v.abs() as f64).sum::<f64>() as f32
}

/// Number of non-zeros `‖x‖₀`.
#[inline]
pub fn norm0(x: &[f32]) -> usize {
    x.iter().filter(|&&v| v != 0.0).count()
}

/// Dot product (f64 accumulation).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum::<f64>() as f32
}

/// `y ← y + alpha·x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x ← alpha·x`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Elementwise difference `a - b`.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// `‖a - b‖₂` without allocating.
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt() as f32
}

/// Row-major dense matrix `A (rows × cols)` times vector: `out = A·x`.
pub fn matvec(a: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &a[r * cols..(r + 1) * cols];
        *o = dot(row, x);
    }
}

/// Row-major dense transposed product: `out = Aᵀ·x` (`x` has `rows` entries).
pub fn matvec_t(a: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), rows);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for (r, &xr) in x.iter().enumerate() {
        if xr == 0.0 {
            continue;
        }
        let row = &a[r * cols..(r + 1) * cols];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += xr * v;
        }
    }
}

/// Indices of the `k` largest-magnitude entries (unordered). `O(n)` average
/// via std's introselect (`select_nth_unstable_by`) on magnitudes — this is
/// the Top-k sparsifier's kernel and beats the paper's
/// `O(k + (n-k)log k)` heap bound for the regimes we run.
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<usize> {
    let mut idx = Vec::new();
    top_k_indices_into(x, k, &mut idx);
    idx
}

/// Allocation-free form of [`top_k_indices`]: fills `out` with the result,
/// reusing its capacity (grows to `x.len()` once). Same selection, same
/// (unordered) output as the allocating form.
pub fn top_k_indices_into(x: &[f32], k: usize, out: &mut Vec<usize>) {
    let n = x.len();
    out.clear();
    if k == 0 {
        return;
    }
    out.extend(0..n);
    if k >= n {
        return;
    }
    out.select_nth_unstable_by(k - 1, |&a, &b| {
        x[b].abs().partial_cmp(&x[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    out.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    #[test]
    fn norms_basic() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-6);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-6);
        assert!((norm1(&x) - 7.0).abs() < 1e-6);
        assert_eq!(norm0(&[0.0, 1.0, 0.0, 2.0]), 2);
    }

    #[test]
    fn dot_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-6);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
    }

    #[test]
    fn matvec_roundtrip_against_transpose() {
        let mut rng = Rng::seed_from(1);
        let (rows, cols) = (7, 5);
        let a: Vec<f32> = (0..rows * cols).map(|_| rng.gaussian_f32()).collect();
        let x: Vec<f32> = (0..cols).map(|_| rng.gaussian_f32()).collect();
        let y: Vec<f32> = (0..rows).map(|_| rng.gaussian_f32()).collect();
        // <Ax, y> == <x, A^T y>
        let mut ax = vec![0.0; rows];
        matvec(&a, rows, cols, &x, &mut ax);
        let mut aty = vec![0.0; cols];
        matvec_t(&a, rows, cols, &y, &mut aty);
        assert!((dot(&ax, &y) - dot(&x, &aty)).abs() < 1e-3);
    }

    #[test]
    fn top_k_into_matches_allocating_with_reused_buffer() {
        let mut rng = Rng::seed_from(3);
        let mut buf = Vec::new();
        for &(n, k) in &[(40usize, 5usize), (7, 7), (9, 0), (64, 13)] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let want = top_k_indices(&x, k);
            top_k_indices_into(&x, k, &mut buf);
            assert_eq!(buf, want, "n={n} k={k}");
        }
    }

    #[test]
    fn top_k_matches_sort() {
        let mut rng = Rng::seed_from(2);
        for &(n, k) in &[(10usize, 3usize), (100, 10), (257, 77), (64, 64), (5, 0)] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut got = top_k_indices(&x, k);
            got.sort_unstable();
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| x[b].abs().partial_cmp(&x[a].abs()).unwrap());
            // Compare magnitude threshold rather than exact indices (ties).
            if k > 0 && k < n {
                let thresh = x[order[k - 1]].abs();
                for &i in &got {
                    assert!(x[i].abs() >= thresh - 1e-6);
                }
            }
            assert_eq!(got.len(), k.min(n));
        }
    }
}
