//! Frame constructions (§2, Appendix J of the paper).
//!
//! A *frame* is a wide matrix `S ∈ R^{n×N}` (`n ≤ N`) with frame bounds
//! `A‖y‖² ≤ ‖Sᵀy‖² ≤ B‖y‖²`. The paper studies three randomized families:
//!
//! * **Randomized Hadamard** `S = P·D·H` — Parseval, stores only signs and
//!   the row sample, applies in `O(N log N)` additions ([`HadamardFrame`]).
//! * **Random (Haar) orthonormal** — Parseval, dense, `O(nN)` apply
//!   ([`OrthonormalFrame`]).
//! * **Sub-Gaussian** — i.i.d. `N(0, 1/N)` entries; only *approximately*
//!   Parseval, needs a pseudo-inverse solve ([`SubGaussianFrame`]).
//!
//! All three implement [`Frame`]; the embedding and quantization layers are
//! generic over it.

use crate::linalg::fwht::{
    fwht_inplace_auto, fwht_normalized_inplace, fwht_normalized_reference_inplace, next_pow2,
};
use crate::linalg::rng::Rng;
use crate::linalg::vecops::{dot, matvec, matvec_t};

/// A (row-)frame `S ∈ R^{n×N}`: the linear maps `x ↦ Sx` and `y ↦ Sᵀy`.
pub trait Frame: Send + Sync {
    /// Original dimension `n` (the space gradients live in).
    fn n(&self) -> usize;
    /// Embedding dimension `N ≥ n`.
    fn big_n(&self) -> usize;
    /// Aspect ratio `λ = N/n` (§2, App. N).
    fn lambda(&self) -> f32 {
        self.big_n() as f32 / self.n() as f32
    }
    /// `out = Sᵀ·y` — for Parseval frames this *is* the near-democratic
    /// embedding (eq. 8). `y.len() == n`, `out.len() == N`.
    fn adjoint(&self, y: &[f32], out: &mut [f32]);
    /// `out = S·x` — the decoder's inverse transform. `x.len() == N`,
    /// `out.len() == n`.
    fn apply(&self, x: &[f32], out: &mut [f32]);
    /// `out = S·x`, treating `x` as destroyable scratch — the
    /// allocation-free twin of [`Frame::apply`]. The default delegates to
    /// `apply` (already allocation-free for dense frames); transform-based
    /// frames override it to run their transform in place on `x`.
    fn apply_inplace(&self, x: &mut [f32], out: &mut [f32]) {
        self.apply(x, out);
    }
    /// Whether `S·Sᵀ = Iₙ` exactly (Parseval / tight with A=B=1).
    fn is_parseval(&self) -> bool {
        true
    }
    /// Minimum-norm pre-image `Sᵀ(SSᵀ)⁻¹y`. Defaults to `Sᵀy`, correct for
    /// Parseval frames; non-Parseval frames override.
    fn pinv_embed(&self, y: &[f32], out: &mut [f32]) {
        self.adjoint(y, out);
    }
    /// Minimum-norm pre-image with caller-provided scratch, so
    /// non-Parseval frames stay allocation-free. The Parseval default
    /// (`Sᵀy`) needs no scratch.
    fn pinv_embed_into(&self, y: &[f32], out: &mut [f32], tmp: &mut Vec<f32>) {
        let _ = tmp;
        self.pinv_embed(y, out);
    }
    /// Deferred-scale minimum-norm pre-image: fill `out` with the embed
    /// *without* its final uniform scaling and return the scale constant
    /// `c > 0`, such that `pinv_embed(y)[i] == out[i] * c` **bitwise**
    /// for every `i` (one IEEE multiply by `c`, exactly the multiply the
    /// unfused path performs in its scaling sweep). Frames whose embed
    /// ends in such a sweep (Hadamard: the FWHT's `1/√N`) return
    /// `Some(c)` so the codec can fold that multiply into its quantize
    /// pass and skip one full sweep over `N` floats; the default returns
    /// `None` and callers fall back to [`Frame::pinv_embed_into`].
    fn pinv_embed_deferred(&self, y: &[f32], out: &mut [f32]) -> Option<f32> {
        let _ = (y, out);
        None
    }
    /// Reference (unfused, scalar-kernel) twin of
    /// [`Frame::pinv_embed_into`]: bit-identical output via the
    /// pre-optimization code path — kept so the fused-kernel equivalence
    /// tier and the hot-path bench have a same-run baseline. The default
    /// (dense frames, which have no fused path) just delegates.
    fn pinv_embed_reference_into(&self, y: &[f32], out: &mut [f32], tmp: &mut Vec<f32>) {
        self.pinv_embed_into(y, out, tmp);
    }
    /// Reference twin of [`Frame::apply_inplace`] — same contract, same
    /// bits, pre-optimization code path (separate transform, scale and
    /// gather sweeps for transform-based frames).
    fn apply_inplace_reference(&self, x: &mut [f32], out: &mut [f32]) {
        self.apply_inplace(x, out);
    }
    /// Heap bytes this frame keeps resident for its lifetime (sign
    /// tables, row samples, dense matrices) — the true figure, not an
    /// estimate, so the serve-layer plan cache can account cached
    /// ladders against its byte cap. Every in-tree frame implements
    /// this; no default, so a new frame cannot silently report zero.
    fn resident_bytes(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Randomized Hadamard frame S = P·D·H
// ---------------------------------------------------------------------------

/// Randomized Hadamard frame `S = P·D·H ∈ R^{n×N}` (§2.1).
///
/// `H` is the normalized `N×N` Hadamard matrix, `D` a random ±1 diagonal and
/// `P` a random row sample. Storage is `N` signs + `n` indices — the paper's
/// "only the signs need to be stored" property — and both `S` and `Sᵀ`
/// apply with one FWHT.
#[derive(Clone)]
pub struct HadamardFrame {
    n: usize,
    big_n: usize,
    /// ±1 diagonal of `D`.
    signs: Vec<f32>,
    /// Row sample: `rows[i]` is the coordinate of `R^N` that forms row `i`
    /// of `P` (distinct, sorted for locality).
    rows: Vec<usize>,
}

impl HadamardFrame {
    /// Build a frame for original dimension `n` with the minimal admissible
    /// `N = 2^⌈log₂n⌉` (the paper recommends λ as close to 1 as possible,
    /// App. N).
    pub fn new(n: usize, rng: &mut Rng) -> Self {
        Self::with_big_n(n, next_pow2(n), rng)
    }

    /// Build with an explicit embedding dimension `N` (power of two, ≥ n) —
    /// used by the App. N tradeoff sweeps (Figs. 8, 9).
    pub fn with_big_n(n: usize, big_n: usize, rng: &mut Rng) -> Self {
        assert!(big_n >= n, "N must be >= n ({big_n} < {n})");
        assert!(big_n.is_power_of_two(), "Hadamard N must be a power of two, got {big_n}");
        let signs: Vec<f32> = (0..big_n).map(|_| rng.sign()).collect();
        let mut rows = rng.sample_indices(big_n, n);
        rows.sort_unstable();
        HadamardFrame { n, big_n, signs, rows }
    }
}

impl Frame for HadamardFrame {
    fn n(&self) -> usize {
        self.n
    }

    fn big_n(&self) -> usize {
        self.big_n
    }

    /// `Sᵀy = H·D·Pᵀy`: scatter, sign-flip, FWHT. `O(N log N)`.
    fn adjoint(&self, y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(y.len(), self.n);
        debug_assert_eq!(out.len(), self.big_n);
        out.fill(0.0);
        for (i, &r) in self.rows.iter().enumerate() {
            out[r] = self.signs[r] * y[i];
        }
        fwht_normalized_inplace(out);
    }

    /// `Sx = P·D·H·x`: FWHT (into scratch), sign-flip + gather.
    fn apply(&self, x: &[f32], out: &mut [f32]) {
        let mut t = x.to_vec();
        self.apply_inplace(&mut t, out);
    }

    /// `Sx` with the FWHT run directly on `x` — zero allocations; this is
    /// what the decode hot path uses every round.
    ///
    /// **Fused:** the unnormalized transform runs first and the `1/√N`
    /// scaling folds into the gather, so only the `n` sampled coordinates
    /// pay the scale multiply instead of a full `N`-sweep. Per gathered
    /// element the op sequence (`(x[r]·scale)` then `signs[r]·…`) is
    /// identical to the unfused transform-scale-gather path, so the
    /// result is bit-identical to [`Frame::apply_inplace_reference`] —
    /// the conformance equivalence tier enforces it.
    fn apply_inplace(&self, x: &mut [f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.big_n);
        debug_assert_eq!(out.len(), self.n);
        fwht_inplace_auto(x);
        let scale = 1.0 / (x.len() as f32).sqrt();
        for (o, &r) in out.iter_mut().zip(&self.rows) {
            *o = self.signs[r] * (x[r] * scale);
        }
    }

    /// Deferred-scale embed: scatter + sign + **unnormalized** FWHT, with
    /// the `1/√N` returned for the caller's own per-element pass.
    fn pinv_embed_deferred(&self, y: &[f32], out: &mut [f32]) -> Option<f32> {
        debug_assert_eq!(y.len(), self.n);
        debug_assert_eq!(out.len(), self.big_n);
        out.fill(0.0);
        for (i, &r) in self.rows.iter().enumerate() {
            out[r] = self.signs[r] * y[i];
        }
        fwht_inplace_auto(out);
        Some(1.0 / (out.len() as f32).sqrt())
    }

    /// Pre-fusion embed: scatter + sign + scalar-kernel normalized FWHT
    /// (full scaling sweep).
    fn pinv_embed_reference_into(&self, y: &[f32], out: &mut [f32], tmp: &mut Vec<f32>) {
        let _ = tmp;
        debug_assert_eq!(y.len(), self.n);
        debug_assert_eq!(out.len(), self.big_n);
        out.fill(0.0);
        for (i, &r) in self.rows.iter().enumerate() {
            out[r] = self.signs[r] * y[i];
        }
        fwht_normalized_reference_inplace(out);
    }

    /// Pre-fusion decode: scalar-kernel normalized FWHT (its own scaling
    /// sweep), then the plain gather.
    fn apply_inplace_reference(&self, x: &mut [f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.big_n);
        debug_assert_eq!(out.len(), self.n);
        fwht_normalized_reference_inplace(x);
        for (o, &r) in out.iter_mut().zip(&self.rows) {
            *o = self.signs[r] * x[r];
        }
    }

    /// `N` diagonal signs plus `n` sampled row indices.
    fn resident_bytes(&self) -> usize {
        self.signs.len() * std::mem::size_of::<f32>()
            + self.rows.len() * std::mem::size_of::<usize>()
    }
}

// ---------------------------------------------------------------------------
// Random orthonormal (Haar) frame
// ---------------------------------------------------------------------------

/// Random orthonormal frame: `n` Haar-uniform orthonormal rows in `R^N`.
///
/// Generated by drawing an `n×N` i.i.d. Gaussian matrix and orthonormalizing
/// its rows with modified Gram–Schmidt — distributionally identical to
/// sampling `n` rows of a Haar `N×N` orthogonal matrix (the paper's §2.1
/// construction) at `O(n²N)` cost instead of `O(N³)`.
#[derive(Clone)]
pub struct OrthonormalFrame {
    n: usize,
    big_n: usize,
    /// Row-major `n × N`.
    s: Vec<f32>,
}

impl OrthonormalFrame {
    /// Haar frame with `N = ⌈λ·n⌉` for an aspect ratio `λ ≥ 1`.
    pub fn with_lambda(n: usize, lambda: f32, rng: &mut Rng) -> Self {
        assert!(lambda >= 1.0);
        let big_n = ((n as f32 * lambda).ceil() as usize).max(n);
        Self::with_big_n(n, big_n, rng)
    }

    /// Haar frame with explicit `N ≥ n`. For λ = 1 this is a random rotation
    /// (the paper notes NDSC generalizes random rotations).
    pub fn with_big_n(n: usize, big_n: usize, rng: &mut Rng) -> Self {
        assert!(big_n >= n);
        let mut s = vec![0.0f32; n * big_n];
        rng.fill_gaussian(&mut s);
        // Modified Gram–Schmidt over rows, with re-orthogonalization for
        // numerical hygiene at large n.
        for i in 0..n {
            for _pass in 0..2 {
                for j in 0..i {
                    let (head, tail) = s.split_at_mut(i * big_n);
                    let rj = &head[j * big_n..(j + 1) * big_n];
                    let ri = &mut tail[..big_n];
                    let c = dot(rj, ri);
                    for (a, &b) in ri.iter_mut().zip(rj) {
                        *a -= c * b;
                    }
                }
            }
            let row = &mut s[i * big_n..(i + 1) * big_n];
            let nrm = crate::linalg::vecops::norm2(row);
            assert!(nrm > 1e-12, "degenerate Gaussian draw");
            let inv = 1.0 / nrm;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        OrthonormalFrame { n, big_n, s }
    }

    /// Raw row-major matrix (used by the LP solver and tests).
    pub fn matrix(&self) -> &[f32] {
        &self.s
    }
}

impl Frame for OrthonormalFrame {
    fn n(&self) -> usize {
        self.n
    }

    fn big_n(&self) -> usize {
        self.big_n
    }

    fn adjoint(&self, y: &[f32], out: &mut [f32]) {
        matvec_t(&self.s, self.n, self.big_n, y, out);
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        matvec(&self.s, self.n, self.big_n, x, out);
    }

    /// The dense row-major `n × N` matrix.
    fn resident_bytes(&self) -> usize {
        self.s.len() * std::mem::size_of::<f32>()
    }
}

// ---------------------------------------------------------------------------
// Sub-Gaussian frame
// ---------------------------------------------------------------------------

/// Sub-Gaussian frame: i.i.d. `N(0, 1/N)` entries (App. J.1).
///
/// Only approximately Parseval (frame bounds `1±ξ` w.h.p.), so the
/// minimum-norm embedding needs the pseudo-inverse `Sᵀ(SSᵀ)⁻¹`, implemented
/// with a Cholesky solve of the `n×n` Gram matrix.
#[derive(Clone)]
pub struct SubGaussianFrame {
    n: usize,
    big_n: usize,
    s: Vec<f32>,
    /// Cholesky factor `L` of `SSᵀ` (lower-triangular, row-major `n×n`),
    /// computed once at construction.
    chol: Vec<f32>,
}

impl SubGaussianFrame {
    pub fn with_lambda(n: usize, lambda: f32, rng: &mut Rng) -> Self {
        assert!(lambda >= 1.0);
        let big_n = ((n as f32 * lambda).ceil() as usize).max(n);
        let scale = 1.0 / (big_n as f32).sqrt();
        let mut s = vec![0.0f32; n * big_n];
        for v in s.iter_mut() {
            *v = rng.gaussian_f32() * scale;
        }
        // Gram = S S^T (n×n), then Cholesky.
        let mut gram = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                let g = dot(&s[i * big_n..(i + 1) * big_n], &s[j * big_n..(j + 1) * big_n]);
                gram[i * n + j] = g;
                gram[j * n + i] = g;
            }
        }
        let chol = cholesky(&gram, n).expect("SS^T should be PD for a Gaussian frame");
        SubGaussianFrame { n, big_n, s, chol }
    }
}

impl Frame for SubGaussianFrame {
    fn n(&self) -> usize {
        self.n
    }

    fn big_n(&self) -> usize {
        self.big_n
    }

    fn adjoint(&self, y: &[f32], out: &mut [f32]) {
        matvec_t(&self.s, self.n, self.big_n, y, out);
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        matvec(&self.s, self.n, self.big_n, x, out);
    }

    fn is_parseval(&self) -> bool {
        false
    }

    /// `Sᵀ(SSᵀ)⁻¹y` via the cached Cholesky factor.
    fn pinv_embed(&self, y: &[f32], out: &mut [f32]) {
        let mut z = Vec::new();
        self.pinv_embed_into(y, out, &mut z);
    }

    /// Allocation-free pseudo-inverse embed: the Cholesky solve runs in
    /// `tmp` (resized to `n`, capacity reused across calls).
    fn pinv_embed_into(&self, y: &[f32], out: &mut [f32], tmp: &mut Vec<f32>) {
        tmp.clear();
        tmp.extend_from_slice(y);
        cholesky_solve(&self.chol, self.n, tmp);
        matvec_t(&self.s, self.n, self.big_n, tmp, out);
    }

    /// Dense `n × N` matrix plus the cached `n × n` Cholesky factor.
    fn resident_bytes(&self) -> usize {
        (self.s.len() + self.chol.len()) * std::mem::size_of::<f32>()
    }
}

// ---------------------------------------------------------------------------
// Small dense Cholesky (substrate — no linear algebra crates on this image)
// ---------------------------------------------------------------------------

/// Cholesky factorization `A = L·Lᵀ` of a symmetric PD `n×n` matrix
/// (row-major). Returns the lower factor, or `None` if not PD.
pub fn cholesky(a: &[f32], n: usize) -> Option<Vec<f32>> {
    debug_assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j] as f64;
            for k in 0..j {
                sum -= (l[i * n + k] as f64) * (l[j * n + k] as f64);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt() as f32;
            } else {
                l[i * n + j] = (sum / l[j * n + j] as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve `L·Lᵀ·x = b` in place given the Cholesky factor `L`.
pub fn cholesky_solve(l: &[f32], n: usize, b: &mut [f32]) {
    // Forward solve L z = b.
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= (l[i * n + k] as f64) * (b[k] as f64);
        }
        b[i] = (sum / l[i * n + i] as f64) as f32;
    }
    // Backward solve L^T x = z.
    for i in (0..n).rev() {
        let mut sum = b[i] as f64;
        for k in i + 1..n {
            sum -= (l[k * n + i] as f64) * (b[k] as f64);
        }
        b[i] = (sum / l[i * n + i] as f64) as f32;
    }
}

/// Dynamic frame selection used throughout the config system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Randomized Hadamard `S = PDH`, `N = 2^⌈log₂n⌉` — the default.
    Hadamard,
    /// Haar orthonormal with λ = 1 (random rotation).
    Orthonormal,
    /// Sub-Gaussian with λ = 2.
    SubGaussian,
}

impl FrameKind {
    pub fn build(self, n: usize, rng: &mut Rng) -> Box<dyn Frame> {
        match self {
            FrameKind::Hadamard => Box::new(HadamardFrame::new(n, rng)),
            FrameKind::Orthonormal => Box::new(OrthonormalFrame::with_big_n(n, n, rng)),
            FrameKind::SubGaussian => Box::new(SubGaussianFrame::with_lambda(n, 2.0, rng)),
        }
    }

    pub fn parse(s: &str) -> Option<FrameKind> {
        match s.to_ascii_lowercase().as_str() {
            "hadamard" | "ndh" => Some(FrameKind::Hadamard),
            "orthonormal" | "haar" | "ndo" => Some(FrameKind::Orthonormal),
            "subgaussian" | "gaussian" => Some(FrameKind::SubGaussian),
            _ => None,
        }
    }
}

impl std::fmt::Display for FrameKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameKind::Hadamard => write!(f, "hadamard"),
            FrameKind::Orthonormal => write!(f, "orthonormal"),
            FrameKind::SubGaussian => write!(f, "subgaussian"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};

    /// `S·Sᵀ = Iₙ` checked through the operator form: for random y,
    /// `S(Sᵀy) = y`.
    fn check_parseval<F: Frame>(frame: &F, rng: &mut Rng, tol: f32) {
        let (n, big_n) = (frame.n(), frame.big_n());
        for _ in 0..5 {
            let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let mut x = vec![0.0; big_n];
            frame.adjoint(&y, &mut x);
            let mut back = vec![0.0; n];
            frame.apply(&x, &mut back);
            assert!(
                dist2(&back, &y) < tol * norm2(&y),
                "S S^T y != y: err={}",
                dist2(&back, &y)
            );
        }
    }

    #[test]
    fn hadamard_is_parseval() {
        let mut rng = Rng::seed_from(1);
        for &n in &[5usize, 30, 100, 116, 1000] {
            let f = HadamardFrame::new(n, &mut rng);
            assert_eq!(f.big_n(), next_pow2(n));
            check_parseval(&f, &mut rng, 1e-4);
        }
    }

    #[test]
    fn apply_inplace_matches_apply() {
        let mut rng = Rng::seed_from(11);
        let f = HadamardFrame::new(100, &mut rng);
        let x: Vec<f32> = (0..f.big_n()).map(|_| rng.gaussian_cubed()).collect();
        let mut want = vec![0.0; 100];
        f.apply(&x, &mut want);
        let mut scratch = x.clone();
        let mut got = vec![0.0; 100];
        f.apply_inplace(&mut scratch, &mut got);
        assert_eq!(got, want, "apply_inplace must be bit-identical to apply");
    }

    /// The fused decode (scale folded into the gather) and the deferred
    /// embed (scale returned, not applied) must be bit-identical to the
    /// unfused reference sweeps: `|a|·c == |a·c|` and max-monotonicity of
    /// the positive scale make the fusion exact, not approximate.
    #[test]
    fn hadamard_fused_paths_bit_identical_to_reference() {
        let mut rng = Rng::seed_from(21);
        for &n in &[37usize, 100, 1024] {
            let f = HadamardFrame::new(n, &mut rng);
            let big_n = f.big_n();
            let x: Vec<f32> = (0..big_n).map(|_| rng.gaussian_cubed()).collect();
            let mut s1 = x.clone();
            let mut want = vec![0.0; n];
            f.apply_inplace_reference(&mut s1, &mut want);
            let mut s2 = x.clone();
            let mut got = vec![0.0; n];
            f.apply_inplace(&mut s2, &mut got);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused apply differs at n={n}"
            );
            let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut full = vec![0.0; big_n];
            f.adjoint(&y, &mut full);
            let mut raw = vec![0.0; big_n];
            let c = f.pinv_embed_deferred(&y, &mut raw).expect("hadamard frames defer the scale");
            assert!(
                raw.iter().map(|&v| v * c).zip(&full).all(|(a, b)| a.to_bits() == b.to_bits()),
                "deferred embed × c differs from full embed at n={n}"
            );
        }
    }

    #[test]
    fn pinv_embed_into_matches_allocating() {
        let mut rng = Rng::seed_from(12);
        let f = SubGaussianFrame::with_lambda(20, 2.0, &mut rng);
        let y: Vec<f32> = (0..20).map(|_| rng.gaussian_f32()).collect();
        let mut want = vec![0.0; f.big_n()];
        f.pinv_embed(&y, &mut want);
        let mut got = vec![0.0; f.big_n()];
        let mut tmp = Vec::new();
        f.pinv_embed_into(&y, &mut got, &mut tmp);
        assert_eq!(got, want);
    }

    #[test]
    fn orthonormal_is_parseval() {
        let mut rng = Rng::seed_from(2);
        for &(n, big_n) in &[(8usize, 8usize), (30, 30), (30, 45), (64, 128)] {
            let f = OrthonormalFrame::with_big_n(n, big_n, &mut rng);
            check_parseval(&f, &mut rng, 1e-3);
        }
    }

    #[test]
    fn orthonormal_rows_are_orthonormal() {
        let mut rng = Rng::seed_from(3);
        let f = OrthonormalFrame::with_big_n(20, 32, &mut rng);
        for i in 0..20 {
            for j in 0..=i {
                let d = dot(&f.s[i * 32..(i + 1) * 32], &f.s[j * 32..(j + 1) * 32]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j}) dot={d}");
            }
        }
    }

    #[test]
    fn subgaussian_pinv_is_right_inverse() {
        let mut rng = Rng::seed_from(4);
        let f = SubGaussianFrame::with_lambda(25, 2.0, &mut rng);
        let y: Vec<f32> = (0..25).map(|_| rng.gaussian_cubed()).collect();
        let mut x = vec![0.0; f.big_n()];
        f.pinv_embed(&y, &mut x);
        let mut back = vec![0.0; 25];
        f.apply(&x, &mut back);
        assert!(dist2(&back, &y) < 1e-2 * norm2(&y));
    }

    #[test]
    fn adjoint_preserves_norm_for_parseval() {
        // ||S^T y||_2 = ||y||_2 when SS^T = I (A = B = 1 frame bounds).
        let mut rng = Rng::seed_from(5);
        let f = HadamardFrame::new(100, &mut rng);
        let y: Vec<f32> = (0..100).map(|_| rng.gaussian_cubed()).collect();
        let mut x = vec![0.0; f.big_n()];
        f.adjoint(&y, &mut x);
        assert!((norm2(&x) - norm2(&y)).abs() < 1e-3 * norm2(&y));
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = M M^T + I is SPD.
        let mut rng = Rng::seed_from(6);
        let n = 10;
        let m: Vec<f32> = (0..n * n).map(|_| rng.gaussian_f32()).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&m[i * n..(i + 1) * n], &m[j * n..(j + 1) * n])
                    + if i == j { 1.0 } else { 0.0 };
            }
        }
        let x_true: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0f32; n];
        matvec(&a, n, n, &x_true, &mut b);
        let l = cholesky(&a, n).unwrap();
        cholesky_solve(&l, n, &mut b);
        assert!(dist2(&b, &x_true) < 1e-3 * (1.0 + norm2(&x_true)));
    }

    #[test]
    fn frame_kind_parse_roundtrip() {
        for k in [FrameKind::Hadamard, FrameKind::Orthonormal, FrameKind::SubGaussian] {
            assert_eq!(FrameKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(FrameKind::parse("nope"), None);
    }
}
