//! Linear-algebra substrate: the deterministic RNG, the fast
//! Walsh–Hadamard transform, frame constructions (§2 of the paper), and
//! small dense-vector helpers used across the crate.

pub mod fwht;
pub mod frames;
pub mod rng;
pub mod vecops;
