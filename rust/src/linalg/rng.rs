//! Deterministic pseudo-random number generation.
//!
//! Everything in this crate that is randomized (frame construction, dithered
//! quantization, data synthesis, property tests) draws from this one
//! xoshiro256++ generator so that experiments are exactly reproducible from
//! a single `u64` seed. No external RNG crates are available on this image,
//! so the generator, the Gaussian sampler and the heavy-tailed samplers the
//! paper's simulations use (Gaussian³, Student-t) are implemented here.

/// xoshiro256++ PRNG (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller pair.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64, used to expand a single `u64` seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a single seed word.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a sub-component (e.g. worker `i`).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Full generator state — the four xoshiro words plus the cached
    /// Box–Muller spare — for checkpointing ([`crate::serve::checkpoint`]).
    /// [`Rng::from_state`] rebuilds a generator that continues the exact
    /// output stream, bit for bit.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Rng { s, gauss_spare }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Random sign, `+1.0` or `-1.0` with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (the spare is cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Draw u in (0,1] to keep ln(u) finite.
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Heavy-tailed `N(0,1)³` sample — the paper raises Gaussian draws to
    /// the third power to get coordinates of widely varying magnitude
    /// (Fig. 1a, Fig. 5, App. N).
    #[inline]
    pub fn gaussian_cubed(&mut self) -> f32 {
        let g = self.gaussian();
        (g * g * g) as f32
    }

    /// Student-t with `df` degrees of freedom. For `df = 1` this is the
    /// Cauchy distribution used for the planted model in Fig. 3a / Fig. 6.
    pub fn student_t(&mut self, df: u32) -> f32 {
        debug_assert!(df >= 1);
        let z = self.gaussian();
        // chi^2_df as a sum of squared normals; df is tiny (1) in the paper.
        let mut chi2 = 0.0;
        for _ in 0..df {
            let g = self.gaussian();
            chi2 += g * g;
        }
        (z / (chi2 / df as f64).sqrt()) as f32
    }

    /// Fill `out` with i.i.d. standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.gaussian_f32();
        }
    }

    /// A uniformly random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// `k` distinct indices sampled uniformly without replacement from
    /// `0..n` (partial Fisher–Yates; O(n) memory, O(k) swaps).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// Allocation-free form of [`Rng::sample_indices`]: fills `out` with
    /// the `k` sampled indices, reusing its capacity (which grows to `n`
    /// once, then never again). Draws the same RNG stream and produces the
    /// same indices as the allocating form.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        debug_assert!(k <= n);
        out.clear();
        out.extend(0..n);
        for i in 0..k {
            let j = i + self.below(n - i);
            out.swap(i, j);
        }
        out.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 100_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var={m2}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Rng::seed_from(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count={c}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Rng::seed_from(5);
        let p = rng.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::seed_from(9);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_into_matches_allocating_with_reused_buffer() {
        let mut a = Rng::seed_from(17);
        let mut b = Rng::seed_from(17);
        let mut buf = Vec::new();
        for &(n, k) in &[(10usize, 3usize), (100, 100), (50, 1), (8, 0)] {
            let want = a.sample_indices(n, k);
            b.sample_indices_into(n, k, &mut buf);
            assert_eq!(buf, want, "n={n} k={k}");
        }
    }

    #[test]
    fn student_t_df1_is_heavy_tailed() {
        let mut rng = Rng::seed_from(13);
        let n = 50_000;
        let big = (0..n).filter(|_| rng.student_t(1).abs() > 10.0).count();
        // Cauchy: P(|X| > 10) = 2/pi * atan(1/10) ~ 0.0635.
        let frac = big as f64 / n as f64;
        assert!((frac - 0.0635).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        let mut a = Rng::seed_from(5);
        // Populate the Box–Muller spare so the snapshot carries it.
        a.gaussian();
        let (s, spare) = a.state();
        assert!(spare.is_some(), "odd gaussian draw must leave a spare");
        let mut b = Rng::from_state(s, spare);
        for _ in 0..16 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.uniform_f32().to_bits(), b.uniform_f32().to_bits());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::seed_from(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
