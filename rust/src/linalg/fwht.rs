//! Fast Walsh–Hadamard transform.
//!
//! The computational hot-spot of Near-Democratic Source Coding with a
//! randomized Hadamard frame `S = PDH` (§2.1) is the multiplication by the
//! normalized Hadamard matrix `H` (`H_ij = ±1/√N`). The iterative butterfly
//! below computes `H·x` in `N·log₂N` additions — no multiplications except a
//! single final scaling pass — matching the paper's `O(n log n)` claim.
//!
//! This is the Rust twin of the Pallas kernel in
//! `python/compile/kernels/hadamard.py`; both are checked against the same
//! naive `O(N²)` oracle.
//!
//! Both transforms are **fully in place** — no scratch, no allocation —
//! which is what lets `Frame::apply_inplace` and the whole compression hot
//! path run allocation-free: the only heap the codec ever touches is the
//! caller's reusable [`crate::quant::Workspace`].

/// In-place **unnormalized** Walsh–Hadamard transform of `x`.
///
/// After the call `x = Ĥ·x₀` where `Ĥ` is the ±1 Hadamard matrix (no `1/√N`
/// factor). `x.len()` must be a power of two.
///
/// The loop is cache-blocked: for small strides the butterflies of several
/// stages are executed on one cache-resident chunk before moving on, which
/// is what the §Perf pass settled on (see `EXPERIMENTS.md` §Perf).
/// Cache block: 16 KiB of f32 — fits comfortably in L1/L2. Local stages
/// (stride < `BLOCK`) run to completion on one cache-resident chunk
/// before the next chunk is touched.
pub const BLOCK: usize = 4096;

pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    // Process strides 1..=n/2. For cache friendliness run "local" stages
    // (within a block of size BLOCK) fully per block, then the global ones.
    // Butterflies use split_at_mut + zip so LLVM drops the bounds checks
    // and autovectorizes (measured 2.4x over indexed loops — §Perf).
    let local = n.min(BLOCK);
    // Local stages, one block at a time.
    for chunk in x.chunks_mut(local) {
        let mut h = 1;
        while h < chunk.len() {
            butterfly_stage(chunk, h);
            h *= 2;
        }
    }
    // Global stages (stride >= BLOCK).
    let mut h = local;
    while h < n {
        butterfly_stage(x, h);
        h *= 2;
    }
}

/// One butterfly stage at stride `h` over the whole slice.
#[inline]
fn butterfly_stage(x: &mut [f32], h: usize) {
    for block in x.chunks_exact_mut(2 * h) {
        let (a, b) = block.split_at_mut(h);
        for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
            let s = *ai + *bi;
            let d = *ai - *bi;
            *ai = s;
            *bi = d;
        }
    }
}

/// In-place **orthonormal** Walsh–Hadamard transform: `x ← H·x` with
/// `H = Ĥ/√N`, so `H·H = I`.
pub fn fwht_normalized_inplace(x: &mut [f32]) {
    fwht_inplace(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Textbook scalar FWHT: one butterfly stage at a time over the whole
/// slice, ascending stride, no cache blocking, no vectorization beyond
/// what the plain loop autovectorizes to. Slower than [`fwht_inplace`]
/// but trivially auditable — this is the **bit-exactness oracle** for
/// every optimized path (blocked, SIMD, multi-threaded): each stage
/// performs the identical `(a+b, a−b)` f32 op pair per element, and
/// butterflies within a stage are independent, so any reordering of the
/// optimized paths must reproduce these bits exactly.
pub fn fwht_reference_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in x.chunks_exact_mut(2 * h) {
            let (a, b) = block.split_at_mut(h);
            for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
                let s = *ai + *bi;
                let d = *ai - *bi;
                *ai = s;
                *bi = d;
            }
        }
        h *= 2;
    }
}

/// Naive `O(N²)` multiply by the ±1 Hadamard matrix — the correctness
/// oracle. `H_ij = (-1)^{popcount(i & j)}` (Sylvester construction).
pub fn hadamard_naive(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut out = vec![0.0f32; n];
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (j, &v) in x.iter().enumerate() {
            let sign = if ((i & j) as u64).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            acc += sign * v as f64;
        }
        *o = acc as f32;
    }
    out
}

/// Smallest power of two `>= n` (the embedding dimension for Hadamard
/// frames: `N = 2^⌈log₂ n⌉`, §5 of the paper).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::testkit::prop::{forall, Cases};

    /// Property (via the in-tree harness): `H` is an involution up to the
    /// normalization — two normalized transforms recover the input — at
    /// every power-of-two length across random heavy-tailed inputs.
    #[test]
    fn prop_normalized_fwht_is_involution() {
        forall(Cases::new("fwht involution", 60), |rng, _| {
            let n = 1usize << rng.below(12); // 1 .. 2048
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut y = x.clone();
            fwht_normalized_inplace(&mut y);
            fwht_normalized_inplace(&mut y);
            assert!(
                dist2(&y, &x) <= 2e-3 * (1.0 + norm2(&x)),
                "n={n}: H(Hx) != x, err {}",
                dist2(&y, &x)
            );
        });
    }

    /// Property: the normalized transform is an isometry — `‖Hx‖₂ = ‖x‖₂`
    /// — for every input shape the generator produces.
    #[test]
    fn prop_normalized_fwht_preserves_l2_norm() {
        forall(Cases::new("fwht norm preservation", 60), |rng, _| {
            let n = 1usize << rng.below(12);
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let before = norm2(&x);
            let mut y = x;
            fwht_normalized_inplace(&mut y);
            let after = norm2(&y);
            assert!(
                (before - after).abs() <= 1e-3 * (1.0 + before),
                "n={n}: ||Hx|| {after} vs ||x|| {before}"
            );
        });
    }

    /// Property: the transform is linear — `Ĥ(a·x + z) = a·Ĥx + Ĥz`.
    #[test]
    fn prop_fwht_is_linear() {
        forall(Cases::new("fwht linearity", 40), |rng, _| {
            let n = 1usize << (1 + rng.below(9)); // 2 .. 512
            let a = rng.gaussian_f32();
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let z: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let mut combined: Vec<f32> = x.iter().zip(&z).map(|(&xi, &zi)| a * xi + zi).collect();
            fwht_inplace(&mut combined);
            let mut hx = x.clone();
            fwht_inplace(&mut hx);
            let mut hz = z.clone();
            fwht_inplace(&mut hz);
            let want: Vec<f32> = hx.iter().zip(&hz).map(|(&xi, &zi)| a * xi + zi).collect();
            assert!(
                dist2(&combined, &want) <= 1e-3 * (1.0 + norm2(&want)),
                "n={n}: linearity violated"
            );
        });
    }

    /// Norm-relative oracle: `dist2(got, Hx) ≤ tol·‖Hx‖₂`. A single
    /// misrouted butterfly perturbs the output by `O(‖x‖₂)`, so unlike
    /// the old loose per-element tolerances (2e-2 at n=8192) this cannot
    /// hide stage-ordering or off-by-one bugs in a rewritten kernel.
    /// Covers n ∈ {1, 2, 4} — the only power-of-two lengths that are not
    /// multiples of the SIMD lane width (8) — through BLOCK and 2·BLOCK
    /// (the cache-blocked global stages).
    #[test]
    fn matches_naive_norm_relative() {
        let mut rng = Rng::seed_from(1);
        for &n in &[1usize, 2, 4, 8, 16, 64, 256, 1024, BLOCK, 2 * BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let want = hadamard_naive(&x);
            let mut got = x;
            fwht_inplace(&mut got);
            let err = dist2(&got, &want);
            assert!(err <= 1e-4 * (1e-6 + norm2(&want)), "n={n}: relative l2 error {err}");
        }
    }

    /// The optimized transform must be **bit-exact** against the textbook
    /// scalar reference at every size class: below/at the lane width, at
    /// the cache-block boundary, and deep into the global stages (2^16,
    /// 2^17) where the naive O(N²) oracle is too slow to run. Blocked /
    /// SIMD / threaded execution only reorders independent butterflies,
    /// so equality here is exact, not approximate.
    #[test]
    fn matches_reference_bit_exact_through_global_stages() {
        let mut rng = Rng::seed_from(2);
        for &n in &[1usize, 2, 4, 8, 64, 1024, BLOCK, 2 * BLOCK, 1 << 16, 1 << 17] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut want = x.clone();
            fwht_reference_inplace(&mut want);
            let mut got = x;
            fwht_inplace(&mut got);
            let mismatches =
                got.iter().zip(&want).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
            assert_eq!(mismatches, 0, "n={n}: {mismatches} coordinates differ bitwise");
        }
    }

    /// The reference itself matches the naive matrix oracle (so the two
    /// oracles cannot drift apart).
    #[test]
    fn reference_matches_naive() {
        let mut rng = Rng::seed_from(6);
        for &n in &[1usize, 4, 32, 512, BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let want = hadamard_naive(&x);
            let mut got = x;
            fwht_reference_inplace(&mut got);
            let err = dist2(&got, &want);
            assert!(err <= 1e-4 * (1e-6 + norm2(&want)), "n={n}: relative l2 error {err}");
        }
    }

    #[test]
    fn normalized_is_involution() {
        let mut rng = Rng::seed_from(3);
        let n = 1024;
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let mut y = x.clone();
        fwht_normalized_inplace(&mut y);
        fwht_normalized_inplace(&mut y);
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn normalized_preserves_l2_norm() {
        let mut rng = Rng::seed_from(4);
        let n = 512;
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_normalized_inplace(&mut y);
        let after: f32 = y.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-2 * before);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0; 3];
        fwht_inplace(&mut x);
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
    }
}
