//! Fast Walsh–Hadamard transform.
//!
//! The computational hot-spot of Near-Democratic Source Coding with a
//! randomized Hadamard frame `S = PDH` (§2.1) is the multiplication by the
//! normalized Hadamard matrix `H` (`H_ij = ±1/√N`). The iterative butterfly
//! below computes `H·x` in `N·log₂N` additions — no multiplications except a
//! single final scaling pass — matching the paper's `O(n log n)` claim.
//!
//! This is the Rust twin of the Pallas kernel in
//! `python/compile/kernels/hadamard.py`; both are checked against the same
//! naive `O(N²)` oracle.
//!
//! The single-threaded transforms are **fully in place** — no scratch, no
//! allocation — which is what lets `Frame::apply_inplace` and the whole
//! compression hot path run allocation-free: the only heap the codec ever
//! touches is the caller's reusable [`crate::quant::Workspace`]. The
//! multi-threaded path ([`fwht_inplace_mt`]) spawns scoped threads and a
//! few small panel Vecs per call; it only engages above
//! [`crate::coordinator::config::MT_FWHT_MIN_DIM`], far past the sizes the
//! `test_alloc.rs` zero-allocation proofs pin down.
//!
//! Kernel structure (measurement protocol and current numbers:
//! `EXPERIMENTS.md` §Perf, regenerated from `BENCH_hotpath.json` each CI
//! run): stages 1/2/4 fuse into a radix-8 register kernel (`fwht8`);
//! stages 8..BLOCK/2 run [`LANES`]-wide on one cache-resident chunk;
//! global stages pass-fuse over `PANEL`-wide column windows so `x` is
//! swept once, not `log2(n/BLOCK)` times. Every optimized path is
//! bit-exact against [`fwht_reference_inplace`] — butterflies within a
//! stage are independent, so re-blocking or threading only reorders
//! identical f32 ops.

/// In-place **unnormalized** Walsh–Hadamard transform of `x`.
///
/// After the call `x = Ĥ·x₀` where `Ĥ` is the ±1 Hadamard matrix (no `1/√N`
/// factor). `x.len()` must be a power of two.
/// Cache block: 16 KiB of f32 — fits comfortably in L1/L2. Local stages
/// (stride < `BLOCK`) run to completion on one cache-resident chunk
/// before the next chunk is touched.
pub const BLOCK: usize = 4096;

pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    // Local stages (stride < BLOCK), one cache-resident chunk at a time.
    let local = n.min(BLOCK);
    for chunk in x.chunks_mut(local) {
        fwht_local(chunk);
    }
    // Global stages (stride >= BLOCK), pass-fused over column panels.
    if n > BLOCK {
        global_stages(x, BLOCK);
    }
}

/// Explicit SIMD lane width of the butterfly kernels: 8 f32 lanes (one
/// AVX2 register / two NEON registers). The fixed-size-array inner loops
/// below compile to full-width vector add/sub without `target-feature`
/// gates — the shapes are exact, so LLVM's autovectorizer has no scalar
/// prologue or epilogue to emit (checked on the generated asm: one
/// `vaddps` + one `vsubps` per 8 lanes on x86-64 with default codegen).
pub const LANES: usize = 8;

/// Column-panel width for the pass-fused global stages: 256 columns of
/// f32 = 1 KiB per row touched, so a full panel (all `n/BLOCK` rows) sits
/// in L1/L2 while *every* global stage runs over it — one memory pass
/// over `x` instead of `log2(n/BLOCK)` passes.
const PANEL: usize = 256;

/// Radix-8 micro-kernel: stages h = 1, 2, 4 fused in registers. The op
/// sequence per element is identical to running the three stages
/// separately (each pair still computes the same `(a+b, a−b)` in stage
/// order), so the result is bit-exact vs [`fwht_reference_inplace`].
#[inline(always)]
fn fwht8(v: &mut [f32; LANES]) {
    for i in [0, 2, 4, 6] {
        let (s, d) = (v[i] + v[i + 1], v[i] - v[i + 1]);
        v[i] = s;
        v[i + 1] = d;
    }
    for i in [0, 1, 4, 5] {
        let (s, d) = (v[i] + v[i + 2], v[i] - v[i + 2]);
        v[i] = s;
        v[i + 2] = d;
    }
    for i in 0..4 {
        let (s, d) = (v[i] + v[i + 4], v[i] - v[i + 4]);
        v[i] = s;
        v[i + 4] = d;
    }
}

/// One butterfly stage over two equal-length disjoint halves at the same
/// stride: `(a, b) ← (a+b, a−b)` lane-wise. The body runs on `[f32; LANES]`
/// chunks so the adds/subs vectorize at full width; the remainder loop
/// only fires for lengths < LANES (n ∈ {1, 2, 4} after the radix-8
/// kernel, i.e. never for h ≥ 8).
#[inline]
fn butterfly_arrays(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let mut ac = a.chunks_exact_mut(LANES);
    let mut bc = b.chunks_exact_mut(LANES);
    for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
        let av: &mut [f32; LANES] = av.try_into().unwrap();
        let bv: &mut [f32; LANES] = bv.try_into().unwrap();
        for i in 0..LANES {
            let (s, d) = (av[i] + bv[i], av[i] - bv[i]);
            av[i] = s;
            bv[i] = d;
        }
    }
    for (ai, bi) in ac.into_remainder().iter_mut().zip(bc.into_remainder()) {
        let (s, d) = (*ai + *bi, *ai - *bi);
        *ai = s;
        *bi = d;
    }
}

/// Full transform of one cache-resident chunk (`len ≤ BLOCK`, power of
/// two): radix-8 micro-kernels for stages 1/2/4, then wide butterflies
/// for stages 8..len/2.
fn fwht_local(chunk: &mut [f32]) {
    let n = chunk.len();
    if n < LANES {
        // n ∈ {1, 2, 4}: too short for the radix-8 kernel.
        let mut h = 1;
        while h < n {
            for block in chunk.chunks_exact_mut(2 * h) {
                let (a, b) = block.split_at_mut(h);
                butterfly_arrays(a, b);
            }
            h *= 2;
        }
        return;
    }
    for v in chunk.chunks_exact_mut(LANES) {
        fwht8(v.try_into().unwrap());
    }
    let mut h = LANES;
    while h < n {
        for block in chunk.chunks_exact_mut(2 * h) {
            let (a, b) = block.split_at_mut(h);
            butterfly_arrays(a, b);
        }
        h *= 2;
    }
}

/// All global stages (stride = `rowlen`, 2·rowlen, …, n/2) viewed as a
/// `(n/rowlen) × rowlen` matrix: an element at row `r`, column `c` only
/// ever pairs with column `c` of row `r ± h/rowlen`, so **columns are
/// independent across every global stage**. That buys two things:
/// pass-fusion (run all stages on one PANEL-wide column window while it
/// is cache-resident — the `mt` path's phase 2 partitions the same
/// windows across threads) and the bit-identity proof (any column
/// partition executes the identical f32 op sequence per element).
fn global_stages(x: &mut [f32], rowlen: usize) {
    let n = x.len();
    let rows = n / rowlen;
    let mut col0 = 0;
    while col0 < rowlen {
        let colw = PANEL.min(rowlen - col0);
        let mut rs = 1; // row stride = h / rowlen
        while rs < rows {
            let mut g = 0;
            while g < rows {
                for ra in g..g + rs {
                    let rb = ra + rs;
                    let (lo, hi) = x.split_at_mut(rb * rowlen);
                    butterfly_arrays(
                        &mut lo[ra * rowlen + col0..ra * rowlen + col0 + colw],
                        &mut hi[col0..col0 + colw],
                    );
                }
                g += 2 * rs;
            }
            rs *= 2;
        }
        col0 += colw;
    }
}

/// Largest power of two `≤ v` (`v ≥ 1`).
fn prev_pow2(v: usize) -> usize {
    debug_assert!(v >= 1);
    1 << (usize::BITS - 1 - v.leading_zeros())
}

/// Multi-threaded in-place FWHT over `std::thread::scope` (rayon-free).
///
/// Phase 1 splits `x` into `T` contiguous chunks (T = largest power of
/// two ≤ `threads` with chunks no smaller than [`BLOCK`]) and runs the
/// full single-threaded transform on each — exactly the stages with
/// stride < n/T. Phase 2 runs the remaining cross-chunk stages
/// partitioned by column windows of the `T × (n/T)` matrix view, which
/// are independent (see `global_stages`). Both phases execute the
/// identical `(a+b, a−b)` f32 ops per element in the same stage order as
/// [`fwht_inplace`], so the result is **bit-identical** to the
/// single-threaded transform — the threshold-boundary tests enforce it.
///
/// Unlike the single-threaded paths this spawns threads and builds
/// per-thread row-slice panels (a few small Vecs per call); callers on
/// the allocation-free hot path only reach it via [`fwht_inplace_auto`]
/// above [`crate::coordinator::config::MT_FWHT_MIN_DIM`], where the
/// transform itself dwarfs that overhead.
pub fn fwht_inplace_mt(x: &mut [f32], threads: usize) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let t = prev_pow2(threads.clamp(1, (n / BLOCK).max(1)).min(64));
    if t <= 1 || n <= BLOCK {
        return fwht_inplace(x);
    }
    let l = n / t; // per-thread chunk length: power of two, >= BLOCK
    // Phase 1: stages with stride < l, each chunk fully local to a thread.
    std::thread::scope(|s| {
        for chunk in x.chunks_mut(l) {
            s.spawn(move || {
                for c in chunk.chunks_mut(BLOCK) {
                    fwht_local(c);
                }
                if l > BLOCK {
                    global_stages(chunk, BLOCK);
                }
            });
        }
    });
    // Phase 2: stages with stride l..n/2 — rows of length l, one thread
    // per disjoint column range (t ranges of width l/t ≥ BLOCK/t).
    let w = l / t;
    let mut panels: Vec<Vec<&mut [f32]>> = (0..t).map(|_| Vec::with_capacity(t)).collect();
    for row in x.chunks_mut(l) {
        let mut rest = row;
        for panel in panels.iter_mut() {
            let (head, tail) = rest.split_at_mut(w);
            panel.push(head);
            rest = tail;
        }
    }
    std::thread::scope(|s| {
        for mut panel in panels {
            s.spawn(move || cross_chunk_stages(&mut panel));
        }
    });
}

/// Phase-2 worker: all butterfly stages across the given row slices
/// (stride doubling from one row upward), pass-fused over PANEL-wide
/// column windows exactly like [`global_stages`].
fn cross_chunk_stages(rows: &mut [&mut [f32]]) {
    let w = rows[0].len();
    let nrows = rows.len();
    let mut off = 0;
    while off < w {
        let cw = PANEL.min(w - off);
        let mut rs = 1;
        while rs < nrows {
            let mut g = 0;
            while g < nrows {
                for ra in g..g + rs {
                    let rb = ra + rs;
                    let (lo, hi) = rows.split_at_mut(rb);
                    butterfly_arrays(&mut lo[ra][off..off + cw], &mut hi[0][off..off + cw]);
                }
                g += 2 * rs;
            }
            rs *= 2;
        }
        off += cw;
    }
}

/// Worker thread count for [`fwht_inplace_auto`], probed once.
fn auto_threads() -> usize {
    use std::sync::OnceLock;
    static T: OnceLock<usize> = OnceLock::new();
    *T.get_or_init(|| std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1).min(16))
}

/// Size-dispatched transform: multi-threaded at or above
/// [`crate::coordinator::config::MT_FWHT_MIN_DIM`] (the single source of
/// truth for the threshold), single-threaded below. Bit-identical either
/// way.
pub fn fwht_inplace_auto(x: &mut [f32]) {
    if x.len() >= crate::coordinator::config::MT_FWHT_MIN_DIM {
        let t = auto_threads();
        if t > 1 {
            return fwht_inplace_mt(x, t);
        }
    }
    fwht_inplace(x);
}

/// In-place **orthonormal** Walsh–Hadamard transform: `x ← H·x` with
/// `H = Ĥ/√N`, so `H·H = I`. Dispatches through [`fwht_inplace_auto`],
/// so the server decode path picks up the multi-threaded kernel for
/// free above the threshold.
pub fn fwht_normalized_inplace(x: &mut [f32]) {
    fwht_inplace_auto(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Reference twin of [`fwht_normalized_inplace`] built on the textbook
/// scalar kernel — the unfused pre-optimization code path kept for the
/// equivalence tier and as the same-run perf baseline in the hot-path
/// bench.
pub fn fwht_normalized_reference_inplace(x: &mut [f32]) {
    fwht_reference_inplace(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Textbook scalar FWHT: one butterfly stage at a time over the whole
/// slice, ascending stride, no cache blocking, no vectorization beyond
/// what the plain loop autovectorizes to. Slower than [`fwht_inplace`]
/// but trivially auditable — this is the **bit-exactness oracle** for
/// every optimized path (blocked, SIMD, multi-threaded): each stage
/// performs the identical `(a+b, a−b)` f32 op pair per element, and
/// butterflies within a stage are independent, so any reordering of the
/// optimized paths must reproduce these bits exactly.
pub fn fwht_reference_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in x.chunks_exact_mut(2 * h) {
            let (a, b) = block.split_at_mut(h);
            for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
                let s = *ai + *bi;
                let d = *ai - *bi;
                *ai = s;
                *bi = d;
            }
        }
        h *= 2;
    }
}

/// Naive `O(N²)` multiply by the ±1 Hadamard matrix — the correctness
/// oracle. `H_ij = (-1)^{popcount(i & j)}` (Sylvester construction).
pub fn hadamard_naive(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut out = vec![0.0f32; n];
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (j, &v) in x.iter().enumerate() {
            let sign = if ((i & j) as u64).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            acc += sign * v as f64;
        }
        *o = acc as f32;
    }
    out
}

/// Smallest power of two `>= n` (the embedding dimension for Hadamard
/// frames: `N = 2^⌈log₂ n⌉`, §5 of the paper).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::testkit::prop::{forall, Cases};

    /// Property (via the in-tree harness): `H` is an involution up to the
    /// normalization — two normalized transforms recover the input — at
    /// every power-of-two length across random heavy-tailed inputs.
    #[test]
    fn prop_normalized_fwht_is_involution() {
        forall(Cases::new("fwht involution", 60), |rng, _| {
            let n = 1usize << rng.below(12); // 1 .. 2048
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut y = x.clone();
            fwht_normalized_inplace(&mut y);
            fwht_normalized_inplace(&mut y);
            assert!(
                dist2(&y, &x) <= 2e-3 * (1.0 + norm2(&x)),
                "n={n}: H(Hx) != x, err {}",
                dist2(&y, &x)
            );
        });
    }

    /// Property: the normalized transform is an isometry — `‖Hx‖₂ = ‖x‖₂`
    /// — for every input shape the generator produces.
    #[test]
    fn prop_normalized_fwht_preserves_l2_norm() {
        forall(Cases::new("fwht norm preservation", 60), |rng, _| {
            let n = 1usize << rng.below(12);
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let before = norm2(&x);
            let mut y = x;
            fwht_normalized_inplace(&mut y);
            let after = norm2(&y);
            assert!(
                (before - after).abs() <= 1e-3 * (1.0 + before),
                "n={n}: ||Hx|| {after} vs ||x|| {before}"
            );
        });
    }

    /// Property: the transform is linear — `Ĥ(a·x + z) = a·Ĥx + Ĥz`.
    #[test]
    fn prop_fwht_is_linear() {
        forall(Cases::new("fwht linearity", 40), |rng, _| {
            let n = 1usize << (1 + rng.below(9)); // 2 .. 512
            let a = rng.gaussian_f32();
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let z: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let mut combined: Vec<f32> = x.iter().zip(&z).map(|(&xi, &zi)| a * xi + zi).collect();
            fwht_inplace(&mut combined);
            let mut hx = x.clone();
            fwht_inplace(&mut hx);
            let mut hz = z.clone();
            fwht_inplace(&mut hz);
            let want: Vec<f32> = hx.iter().zip(&hz).map(|(&xi, &zi)| a * xi + zi).collect();
            assert!(
                dist2(&combined, &want) <= 1e-3 * (1.0 + norm2(&want)),
                "n={n}: linearity violated"
            );
        });
    }

    /// Norm-relative oracle: `dist2(got, Hx) ≤ tol·‖Hx‖₂`. A single
    /// misrouted butterfly perturbs the output by `O(‖x‖₂)`, so unlike
    /// the old loose per-element tolerances (2e-2 at n=8192) this cannot
    /// hide stage-ordering or off-by-one bugs in a rewritten kernel.
    /// Covers n ∈ {1, 2, 4} — the only power-of-two lengths that are not
    /// multiples of the SIMD lane width (8) — through BLOCK and 2·BLOCK
    /// (the cache-blocked global stages).
    #[test]
    fn matches_naive_norm_relative() {
        let mut rng = Rng::seed_from(1);
        for &n in &[1usize, 2, 4, 8, 16, 64, 256, 1024, BLOCK, 2 * BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let want = hadamard_naive(&x);
            let mut got = x;
            fwht_inplace(&mut got);
            let err = dist2(&got, &want);
            assert!(err <= 1e-4 * (1e-6 + norm2(&want)), "n={n}: relative l2 error {err}");
        }
    }

    /// The optimized transform must be **bit-exact** against the textbook
    /// scalar reference at every size class: below/at the lane width, at
    /// the cache-block boundary, and deep into the global stages (2^16,
    /// 2^17) where the naive O(N²) oracle is too slow to run. Blocked /
    /// SIMD / threaded execution only reorders independent butterflies,
    /// so equality here is exact, not approximate.
    #[test]
    fn matches_reference_bit_exact_through_global_stages() {
        let mut rng = Rng::seed_from(2);
        for &n in &[1usize, 2, 4, 8, 64, 1024, BLOCK, 2 * BLOCK, 1 << 16, 1 << 17] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut want = x.clone();
            fwht_reference_inplace(&mut want);
            let mut got = x;
            fwht_inplace(&mut got);
            let mismatches =
                got.iter().zip(&want).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
            assert_eq!(mismatches, 0, "n={n}: {mismatches} coordinates differ bitwise");
        }
    }

    /// The reference itself matches the naive matrix oracle (so the two
    /// oracles cannot drift apart).
    #[test]
    fn reference_matches_naive() {
        let mut rng = Rng::seed_from(6);
        for &n in &[1usize, 4, 32, 512, BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let want = hadamard_naive(&x);
            let mut got = x;
            fwht_reference_inplace(&mut got);
            let err = dist2(&got, &want);
            assert!(err <= 1e-4 * (1e-6 + norm2(&want)), "n={n}: relative l2 error {err}");
        }
    }

    #[test]
    fn normalized_is_involution() {
        let mut rng = Rng::seed_from(3);
        let n = 1024;
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let mut y = x.clone();
        fwht_normalized_inplace(&mut y);
        fwht_normalized_inplace(&mut y);
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn normalized_preserves_l2_norm() {
        let mut rng = Rng::seed_from(4);
        let n = 512;
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_normalized_inplace(&mut y);
        let after: f32 = y.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-2 * before);
    }

    /// The multi-threaded transform is bitwise-equal to single-threaded at
    /// the `MT_FWHT_MIN_DIM` threshold boundaries. `n = threshold ± one
    /// block` is not a power of two (FWHT lengths must be), so the
    /// boundary is bracketed at the nearest admissible sizes instead:
    /// threshold/2 (below — `fwht_inplace_auto` stays single-threaded),
    /// threshold (at — auto goes multi-threaded), and 2×threshold.
    #[test]
    fn mt_bitwise_equal_to_st_at_threshold_boundaries() {
        use crate::coordinator::config::MT_FWHT_MIN_DIM;
        let mut rng = Rng::seed_from(7);
        for &n in &[MT_FWHT_MIN_DIM / 2, MT_FWHT_MIN_DIM, 2 * MT_FWHT_MIN_DIM] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut want = x.clone();
            fwht_inplace(&mut want);
            // Non-power-of-two and over-subscribed thread counts must clamp,
            // not corrupt.
            for t in [2usize, 3, 8] {
                let mut got = x.clone();
                fwht_inplace_mt(&mut got, t);
                let mism =
                    got.iter().zip(&want).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
                assert_eq!(mism, 0, "n={n} threads={t}: {mism} coordinates differ bitwise");
            }
            let mut auto = x.clone();
            fwht_inplace_auto(&mut auto);
            assert!(
                auto.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "auto-dispatched transform differs at n={n}"
            );
        }
    }

    /// Below/at one block the MT entry point must fall back to the
    /// single-threaded kernel (no cross-chunk stages exist).
    #[test]
    fn mt_falls_back_below_block() {
        let mut rng = Rng::seed_from(8);
        for &n in &[8usize, 256, BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let mut want = x.clone();
            fwht_inplace(&mut want);
            let mut got = x;
            fwht_inplace_mt(&mut got, 8);
            assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "n={n}");
        }
    }

    #[test]
    fn normalized_reference_matches_normalized() {
        let mut rng = Rng::seed_from(9);
        for &n in &[64usize, BLOCK, 2 * BLOCK] {
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let mut want = x.clone();
            fwht_normalized_reference_inplace(&mut want);
            let mut got = x;
            fwht_normalized_inplace(&mut got);
            assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0; 3];
        fwht_inplace(&mut x);
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
    }
}
